"""Fleet router tests: dispatch policy, aggregation, streaming, bundle wiring.

Dispatch is pure (``router.dispatch`` has no side effects), so the policy
tests poke engine state directly; the end-to-end tests drive real toy-model
engines and one small two-device tuned bundle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.engine import Request, ServingEngine
from repro.serve.router import Router


class ToyModel:
    """Echo+1 LM (see test_serve_engine): next token = last + 1 mod vocab."""

    vocab = 17

    def init_cache(self, b, cache_len):
        return {
            "k": jnp.zeros((b, cache_len), jnp.float32),
            "mem": jnp.zeros((2, b, 4), jnp.float32),
        }

    def prefill(self, params, batch, cache_len):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, cache_len)
        cache["k"] = cache["k"].at[:, :s].set(tokens.astype(jnp.float32))
        logits = jax.nn.one_hot((tokens[:, -1:] + 1) % self.vocab, self.vocab)
        return logits, cache

    def decode_step(self, params, cache, tokens, positions):
        b = tokens.shape[0]
        cache = dict(cache)
        cache["k"] = cache["k"].at[jnp.arange(b), positions].set(
            tokens[:, 0].astype(jnp.float32)
        )
        logits = jax.nn.one_hot((tokens + 1) % self.vocab, self.vocab)
        return logits, cache


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 32)
    kw.setdefault("prefill_buckets", (8, 16))
    return ServingEngine(ToyModel(), params={}, **kw)


def _router(n=2):
    return Router({f"dev{i}": _engine() for i in range(n)}, name="test")


def _prompt(n=4, start=3):
    return np.arange(start, start + n, dtype=np.int32)


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------
def test_dispatch_least_loaded_with_name_tiebreak():
    router = _router()
    assert router.dispatch() == "dev0"  # tie: lexicographic
    router.engines["dev0"].submit(_prompt())
    assert router.dispatch() == "dev1"  # dev0 now has queue occupancy
    router.engines["dev1"].submit(_prompt())
    router.engines["dev1"].submit(_prompt())
    assert router.dispatch() == "dev0"


def test_dispatch_avoids_degraded_engines():
    router = _router()
    router.engines["dev0"].health = "degraded"
    assert router.dispatch() == "dev1"
    # a fully degraded fleet still serves (least-loaded among degraded)
    router.engines["dev1"].health = "degraded"
    assert router.dispatch() == "dev0"
    assert router.status().health == "degraded"


def test_dispatch_routes_slo_traffic_away_from_pressured_engines():
    router = _router()
    router.engines["dev0"]._slo_mode = True
    # untargeted traffic still balances on load (dev0 is emptier or tied)
    assert router.dispatch() == "dev0"
    # latency-targeted traffic avoids the width-capped engine
    assert router.dispatch(latency_target_ms=5.0) == "dev1"
    # unless every engine is under pressure
    router.engines["dev1"]._slo_mode = True
    assert router.dispatch(latency_target_ms=5.0) == "dev0"


def test_submit_tags_route_and_balances():
    router = _router()
    tickets = [router.submit(_prompt(), max_new_tokens=4) for _ in range(4)]
    routes = [t.request.routed_to for t in tickets]
    assert set(routes) == {"dev0", "dev1"}  # spread, not piled on one engine
    status = router.drain()
    assert status.completed == 4 and not status.exhausted
    assert all(t.done for t in tickets)


# ---------------------------------------------------------------------------
# serving surface
# ---------------------------------------------------------------------------
def test_router_ticket_streams_across_the_fleet():
    router = _router()
    # Park work on BOTH engines; streaming one ticket must advance the other
    # engine too (the ticket steps the router, not a single engine).
    t0 = router.submit(_prompt(), max_new_tokens=5)
    t1 = router.submit(_prompt(start=7), max_new_tokens=5)
    assert t0.request.routed_to != t1.request.routed_to
    toks = list(t0.tokens())
    assert len(toks) == 5 and t0.done
    assert len(t1.request.output) > 0  # fleet progressed while we streamed
    router.drain()
    assert t1.done


def test_submit_request_respects_slo_dispatch():
    router = _router()
    router.engines["dev0"]._slo_mode = True
    req = Request(uid=99, prompt=_prompt(), max_new_tokens=3,
                  latency_target_ms=50.0)
    ticket = router.submit_request(req)
    assert req.routed_to == "dev1"
    assert ticket.result() == req.output and req.done


def test_drain_aggregates_per_engine_statuses():
    router = _router()
    for i in range(5):
        router.submit(_prompt(start=2 + i), max_new_tokens=3)
    status = router.drain()
    assert status.completed == 5
    assert status.in_flight == 0 and status.queued == 0
    per_engine = [router.engines[k].steps for k in sorted(router.engines)]
    assert status.steps == max(per_engine)  # wall-clock analogue, not the sum
    assert status.health == "healthy"
    assert router.healths() == {"dev0": "healthy", "dev1": "healthy"}


def test_router_rejects_empty_fleet():
    with pytest.raises(ValueError):
        Router({})


def test_router_from_engine_iterable_keys_by_position():
    engines = [_engine(), _engine()]
    router = Router(engines)
    assert sorted(router.engines) == ["engine0", "engine1"]


# ---------------------------------------------------------------------------
# bundle.router() wiring
# ---------------------------------------------------------------------------
def test_bundle_router_builds_isolated_engines():
    from repro.core.tuner import tune_fleet

    fleet = tune_fleet(["granite-8b"], device_names=("tpu_v5e", "tpu_v4"),
                       n_kernels=2, max_problems=15)
    bundle = fleet.bundle
    router = bundle.router(ToyModel(), params={}, max_batch=2, cache_len=32,
                           block_size=8, prefill_buckets=(8, 16))
    assert sorted(router.engines) == ["tpu_v4", "tpu_v5e"]
    runtimes = {eng.runtime for eng in router.engines.values()}
    assert len(runtimes) == 2  # one isolated KernelRuntime per device
    for dev, eng in router.engines.items():
        assert eng.runtime.active_device() == dev
    tickets = [router.submit(_prompt(start=1 + i), max_new_tokens=3)
               for i in range(4)]
    status = router.drain()
    assert status.completed == 4
    assert {t.request.routed_to for t in tickets} == {"tpu_v4", "tpu_v5e"}


# ---------------------------------------------------------------------------
# prefix-affinity dispatch + pool-health aggregation
# ---------------------------------------------------------------------------
class ChunkToyModel(ToyModel):
    """Echo+1 toy that also speaks the chunked-prefill protocol."""

    def supports_chunked_prefill(self):
        return True

    def prefill_chunk(self, params, cache, tokens, start, last_row=None):
        cache = dict(cache)
        pos = start + jnp.arange(tokens.shape[1])
        cache["k"] = cache["k"].at[:, pos].set(
            tokens.astype(jnp.float32), mode="drop"
        )
        if last_row is None:
            last = tokens[:, -1:]
        else:
            last = jax.lax.dynamic_slice_in_dim(
                tokens, jnp.asarray(last_row, jnp.int32), 1, axis=1
            )
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab)
        return logits, cache


def _sharing_router(n=2):
    engines = {
        f"dev{i}": ServingEngine(
            ChunkToyModel(), params={}, max_batch=2, cache_len=64,
            block_size=8, prefill_buckets=(8, 16), prefill_chunk_tokens=16,
        )
        for i in range(n)
    }
    return Router(engines, name="test")


def test_dispatch_follows_cached_prefix():
    router = _sharing_router()
    sys_prompt = list(range(1, 17))  # two full 8-token blocks once registered
    t = router.submit(sys_prompt + [3], max_new_tokens=2)
    assert t.request.routed_to == "dev0"
    router.drain()
    # dev0 now caches the system prompt (retired lane keeps it indexed).
    # Load dev0's queue so plain balancing would pick dev1 ...
    router.engines["dev0"].submit(list(range(40, 50)), max_new_tokens=4)
    assert router.dispatch() == "dev1"
    # ... but a same-prefix prompt must follow the cached blocks to dev0
    assert router.dispatch(prompt=sys_prompt + [9]) == "dev0"
    # and the probe is read-only: no lookup/hit counters moved
    assert router.engines["dev0"].status().prefix_lookups == 1  # admission only


def test_affinity_ignores_engines_without_overlap():
    router = _sharing_router()
    # nothing cached anywhere: prompt-aware dispatch falls back to load
    assert router.dispatch(prompt=list(range(1, 17))) == "dev0"


def test_status_aggregates_pool_health():
    router = _sharing_router()
    sys_prompt = list(range(1, 17))
    for tail in ([3], [5], [7], [9]):
        router.submit(sys_prompt + tail, max_new_tokens=2)
    router.drain()
    fleet = router.status()
    per = [router.engines[k].status() for k in sorted(router.engines)]
    assert fleet.prefix_lookups == sum(s.prefix_lookups for s in per) > 0
    assert fleet.prefix_hits == sum(s.prefix_hits for s in per)
    assert fleet.shared_blocks == sum(s.shared_blocks for s in per)
    assert fleet.pool_utilization == pytest.approx(
        sum(s.pool_utilization for s in per) / len(per)
    )
    assert fleet.pool_fragmentation == pytest.approx(
        sum(s.pool_fragmentation for s in per) / len(per)
    )
    assert 0.0 <= fleet.prefix_hit_rate <= 1.0
