"""Multi-device DeploymentBundle: detection, fallback, round-trip, install."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.bundle import (
    BundleFormatError,
    BundleIntegrityError,
    DeploymentBundle,
    install_bundle,
)
from repro.core.codegen import bundle_to_python
from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.devices import (
    DEVICE_ENV_VAR,
    canonical_device_name,
    detect_device,
    resolve_device,
)
from repro.core.dispatch import Deployment, train_deployment
from repro.core.selection import select_from_dataset
from repro.core.tuner import save_fleet, tune_fleet
from repro.kernels import ops
from repro.core.runtime import default_runtime as rt
from repro.core.runtime import reset_default_runtime


@pytest.fixture(autouse=True)
def _clean_policy():
    # Fresh default runtime per test: no hand-maintained clear_* choreography.
    yield
    reset_default_runtime()


def _mini_deployment(device_name: str, n_kernels: int = 5, seed: int = 0) -> Deployment:
    ds = build_model_dataset(synthetic_problems(60, seed=seed), device_name=device_name)
    tr, _ = ds.split()
    chosen = select_from_dataset(tr, n_kernels, "kmeans", "standard", seed=seed)
    return train_deployment(tr, chosen, "DecisionTreeB")


@pytest.fixture(scope="module")
def bundle2() -> DeploymentBundle:
    return DeploymentBundle(
        {
            "tpu_v5e": _mini_deployment("tpu_v5e"),
            "tpu_v4": _mini_deployment("tpu_v4", n_kernels=4, seed=1),
        },
        meta={"archs": "synthetic"},
    )


# ---------------------------------------------------------------------------
# device canonicalization + detection
# ---------------------------------------------------------------------------
def test_canonical_device_name():
    assert canonical_device_name("TPU v5 lite") == "tpu_v5e"
    assert canonical_device_name("TPU v5e") == "tpu_v5e"
    assert canonical_device_name("TPU v4") == "tpu_v4"
    assert canonical_device_name("TPU v4i") == "tpu_v4"
    assert canonical_device_name("TPU v5p") == "tpu_v5p"
    assert canonical_device_name("cpu") == "host_cpu"
    assert canonical_device_name("", "cpu") == "host_cpu"
    assert canonical_device_name("NVIDIA H100", "gpu") == "gpu_nvidia_h100"
    # canonical slugs are fixed points
    for name in ("tpu_v5e", "tpu_v4", "host_cpu"):
        assert canonical_device_name(name) == name


def test_detect_device_env_override(monkeypatch):
    monkeypatch.setenv(DEVICE_ENV_VAR, "TPU v4")
    assert detect_device() == "tpu_v4"
    monkeypatch.delenv(DEVICE_ENV_VAR)
    # this CI/container host has no accelerator
    assert detect_device() == "host_cpu"


def test_resolve_device_order():
    avail = ["tpu_v5e", "tpu_v4", "host_cpu"]
    assert resolve_device("tpu_v4", avail) == "tpu_v4"  # exact
    assert resolve_device("tpu_v5p", avail) == "tpu_v4"  # fallback chain
    assert resolve_device("tpu_v7", ["tpu_v5e", "host_cpu"]) == "tpu_v5e"  # family
    assert resolve_device("gpu_h100", ["tpu_v4"]) == "tpu_v4"  # last resort
    assert resolve_device("gpu_h100", []) is None
    with pytest.raises(KeyError):
        resolve_device("gpu_h100", ["tpu_v4"], strict=True)


# ---------------------------------------------------------------------------
# bundle round-trip + back-compat
# ---------------------------------------------------------------------------
def test_bundle_roundtrip_two_devices(tmp_path, bundle2):
    path = tmp_path / "bundle.json"
    bundle2.save(path)
    blob = json.loads(path.read_text())
    assert blob["version"] == 6 and blob["format"] == "bundle"
    assert blob["deployments"]["tpu_v5e"]["version"] == 5  # embeds v5 blobs
    assert blob["checksums"]  # v6: per-section CRCs over every device blob
    back = DeploymentBundle.load(path)
    assert back.devices == ["tpu_v4", "tpu_v5e"]
    for name in back.devices:
        a, b = back.deployments[name], bundle2.deployments[name]
        assert a.configs == b.configs
        for p in [(64, 256, 512, 1), (1, 4096, 1024, 1), (2048, 2048, 2048, 8)]:
            assert a.select_matmul(*p) == b.select_matmul(*p)
    # the two devices genuinely carry different tuned artifacts
    assert back.deployments["tpu_v4"].configs != back.deployments["tpu_v5e"].configs


def test_bundle_loads_single_device_files(tmp_path, bundle2):
    """v1 and v2 single-device artifacts are degenerate one-entry bundles."""
    dep = bundle2.deployments["tpu_v5e"]
    for fmt in ("flat", "nested"):  # v2 and v1 payloads
        p = tmp_path / f"dep_{fmt}.json"
        dep.save(p, tree_format=fmt)
        wrapped = DeploymentBundle.load(p)
        assert wrapped.devices == ["tpu_v5e"]
        assert wrapped.deployments["tpu_v5e"].configs == dep.configs


def test_bundle_rejects_future_version(bundle2):
    blob = bundle2.to_blob()
    blob["version"] = 99
    with pytest.raises(ValueError, match="newer than supported"):
        DeploymentBundle.from_blob(blob)


def test_bundle_normalizes_device_keys():
    dep = _mini_deployment("tpu_v5e")
    b = DeploymentBundle({"TPU v5 lite": dep})
    assert b.devices == ["tpu_v5e"]


# ---------------------------------------------------------------------------
# install + per-device ops registry
# ---------------------------------------------------------------------------
def test_install_bundle_picks_detected_device(monkeypatch, bundle2):
    monkeypatch.setenv(DEVICE_ENV_VAR, "tpu_v4")
    dep = install_bundle(bundle2)
    assert dep is bundle2.deployments["tpu_v4"]
    assert ops.active_device() == "tpu_v4"
    assert ops.get_kernel_policy() is dep
    assert set(ops.device_policies()) == {"tpu_v4", "tpu_v5e"}


def test_install_bundle_untuned_host_falls_back(monkeypatch, bundle2):
    """An untuned v5p host degrades to its nearest tuned sibling (tpu_v4)."""
    monkeypatch.setenv(DEVICE_ENV_VAR, "tpu_v5p")
    dep = install_bundle(bundle2)
    assert dep is bundle2.deployments["tpu_v4"]
    assert ops.active_device() == "tpu_v4"
    assert ops.device_resolution() == ("tpu_v5p", "tpu_v4")
    assert "fallback_for" not in dep.meta  # shared artifacts are not mutated
    # the selections served are the tuned sibling's, not FixedPolicy defaults
    cfg = ops.select_matmul_config(512, 784, 512, 16)
    assert cfg in dep.configs


def test_install_bundle_replaces_stale_registrations(monkeypatch, bundle2):
    """A prior install's policies must not shadow this bundle's resolution."""
    stale = _mini_deployment("tpu_v5e", n_kernels=3, seed=7)
    rt().install_for_device("tpu_v5p", stale)  # from an earlier install
    monkeypatch.setenv(DEVICE_ENV_VAR, "tpu_v5p")
    dep = install_bundle(bundle2)  # bundle2 has no tpu_v5p entry
    # resolution happened within the bundle: fallback to tpu_v4, not stale
    assert dep is bundle2.deployments["tpu_v4"]
    assert ops.get_kernel_policy() is dep
    assert ops.device_resolution() == ("tpu_v5p", "tpu_v4")
    assert set(ops.device_policies()) == {"tpu_v4", "tpu_v5e"}


def test_clear_device_policies_deactivates_live_policy(monkeypatch, bundle2):
    monkeypatch.setenv(DEVICE_ENV_VAR, "tpu_v5e")
    install_bundle(bundle2)
    assert ops.get_kernel_policy() is not None
    rt().clear_device_policies()
    # the registry-owned live policy is uninstalled with the registry
    assert ops.get_kernel_policy() is None and ops.active_device() is None
    # a manual (non-registry) install survives a registry clear
    manual = bundle2.deployments["tpu_v4"]
    rt().install(manual)
    rt().clear_device_policies()
    assert ops.get_kernel_policy() is manual


def test_install_bundle_strict_raises(monkeypatch, bundle2):
    monkeypatch.setenv(DEVICE_ENV_VAR, "gpu_h100")
    with pytest.raises(KeyError):
        install_bundle(bundle2, strict=True)
    # non-strict still serves *something* tuned
    dep = install_bundle(bundle2)
    assert dep in bundle2.deployments.values()


def test_ops_device_registry_semantics(bundle2):
    v5e = bundle2.deployments["tpu_v5e"]
    v4 = bundle2.deployments["tpu_v4"]
    rt().install_for_device("tpu_v5e", v5e)
    rt().install_for_device("tpu_v4", v4)
    assert ops.get_kernel_policy() is None  # registration does not activate
    assert rt().activate_device("tpu_v5e") == "tpu_v5e"
    assert ops.get_kernel_policy() is v5e
    # re-registering the active device refreshes the live policy
    rt().install_for_device("tpu_v5e", v4)
    assert ops.get_kernel_policy() is v4
    # dropping the live device's policy deactivates it — no stale marker
    rt().install_for_device("tpu_v5e", None)
    assert ops.active_device() is None and ops.get_kernel_policy() is None
    assert ops.device_resolution() == (None, None)
    rt().install_for_device("tpu_v5e", v5e)
    rt().activate_device("tpu_v5e")
    # a manual single-device install detaches from the registry
    rt().install(v5e)
    assert ops.active_device() is None
    rt().clear_device_policies()
    with pytest.raises(KeyError):
        rt().activate_device("tpu_v5e")


def test_serving_engine_consumes_bundle(monkeypatch, bundle2):
    from test_serve_engine import ToyModel

    from repro.serve.engine import Request, ServingEngine

    monkeypatch.setenv(DEVICE_ENV_VAR, "tpu_v5e")
    eng = ServingEngine(ToyModel(), params={}, max_batch=1, cache_len=32,
                        prefill_buckets=(8,), bundle=bundle2)
    assert eng.device == "tpu_v5e"
    assert eng.deployment is bundle2.deployments["tpu_v5e"]
    assert ops.get_kernel_policy() is eng.deployment
    req = Request(uid=0, prompt=np.array([1, 2, 3], dtype=np.int32), max_new_tokens=2)
    status = eng.run([req])
    assert status.completed == 1


# ---------------------------------------------------------------------------
# fleet tuning
# ---------------------------------------------------------------------------
def test_tune_fleet_two_devices(tmp_path):
    fleet = tune_fleet(["granite-8b"], device_names=("tpu_v5e", "tpu_v4"),
                       n_kernels=4, max_problems=40)
    assert sorted(fleet.results) == ["tpu_v4", "tpu_v5e"]
    for name, res in fleet.results.items():
        assert res.oracle_fraction > 0.7
        assert fleet.bundle.deployments[name] is res.deployment
        assert res.deployment.meta["oracle_fraction"] == res.oracle_fraction
    path = tmp_path / "fleet.json"
    save_fleet(fleet, path)
    back = DeploymentBundle.load(path)
    assert back.devices == ["tpu_v4", "tpu_v5e"]
    assert back.meta["archs"] == ["granite-8b"]
    dep, resolved = back.deployment_for("tpu_v5e")
    assert resolved == "tpu_v5e" and len(dep.configs) == 4


# ---------------------------------------------------------------------------
# structured load errors + v6 section checksums (DESIGN.md §11)
# ---------------------------------------------------------------------------
DATA = Path(__file__).parent / "data"
FIXTURES = [  # every committed artifact version, v1 through v5
    "dep_v1.json", "dep_v2.json", "bundle_v3.json", "bundle_v4.json",
    "bundle_v5.json",
]


@pytest.fixture(scope="module")
def bundle_fam(bundle2) -> DeploymentBundle:
    """bundle2 with a wkv family tuning attached, so v6 blobs carry a
    per-family checksum section worth corrupting."""
    from repro.core.cluster import select_configs
    from repro.core.dispatch import build_labels
    from repro.core.families import build_family_dataset, get_family
    from repro.core.normalize import normalize

    fam = get_family("wkv")
    ds = build_family_dataset("wkv", device_name="tpu_v5e")
    chosen = select_configs(normalize(ds.perf, "standard"), 3, "kmeans", seed=0)
    tree = fam.make_tree()
    tree.fit(fam.features(ds.problems), build_labels(ds.perf, chosen))
    cfgs = list(fam.config_space())
    dep = bundle2.deployments["tpu_v5e"].clone()
    dep.set_family_tuning("wkv", [cfgs[i] for i in chosen], tree)
    return DeploymentBundle(
        {"tpu_v5e": dep, "tpu_v4": bundle2.deployments["tpu_v4"]}
    )


@pytest.mark.parametrize("fixture", FIXTURES)
def test_committed_fixtures_load_clean(fixture):
    b = DeploymentBundle.load(DATA / fixture)
    assert b.devices and not b.load_errors


@pytest.mark.parametrize("fixture", FIXTURES)
def test_truncated_fixture_raises_structured_error(tmp_path, fixture):
    """A blob cut off mid-write fails as BundleFormatError with the byte
    offset where decoding stopped — never a bare JSONDecodeError."""
    text = (DATA / fixture).read_text()
    for frac in (0.3, 0.8):
        p = tmp_path / f"t{int(frac * 100)}_{fixture}"
        p.write_text(text[: int(len(text) * frac)])
        with pytest.raises(BundleFormatError) as ei:
            DeploymentBundle.load(p)
        assert ei.value.offset is not None
        assert isinstance(ei.value, ValueError)  # callers catching ValueError keep working


def test_garbage_blob_raises_structured_error(tmp_path):
    p = tmp_path / "garbage.json"
    p.write_text("definitely not a bundle {{{")
    with pytest.raises(BundleFormatError) as ei:
        DeploymentBundle.load(p)
    assert ei.value.offset == 0
    # valid JSON of the wrong shape is a format error too, not a TypeError
    p2 = tmp_path / "list.json"
    p2.write_text("[1, 2, 3]")
    with pytest.raises(BundleFormatError, match="JSON object"):
        DeploymentBundle.load(p2)


@pytest.mark.parametrize("fixture", ["bundle_v3.json", "bundle_v4.json",
                                     "bundle_v5.json"])
def test_mangled_bundle_sections_name_the_section(fixture):
    blob = json.loads((DATA / fixture).read_text())
    with pytest.raises(BundleFormatError) as ei:
        DeploymentBundle.from_blob(dict(blob, version="vX"))
    assert ei.value.section == "version"
    with pytest.raises(BundleFormatError) as ei:
        DeploymentBundle.from_blob(dict(blob, deployments=7))
    assert ei.value.section == "deployments"
    # a structurally gutted device blob names the device it belongs to
    bad = json.loads((DATA / fixture).read_text())
    name = sorted(bad["deployments"])[0]
    bad["deployments"][name] = {"version": 5}
    with pytest.raises(BundleFormatError) as ei:
        DeploymentBundle.from_blob(bad)
    assert ei.value.section == f"deployments.{name}"


@pytest.mark.parametrize("fixture", ["dep_v1.json", "dep_v2.json"])
def test_mangled_single_device_fixture(fixture):
    blob = json.loads((DATA / fixture).read_text())
    blob.pop("configs", None)
    with pytest.raises(BundleFormatError) as ei:
        DeploymentBundle.from_blob(blob)
    assert ei.value.section == "deployment"


def test_v6_corrupt_device_core_recovers_via_fallbacks(bundle2):
    blob = bundle2.to_blob()
    blob["deployments"]["tpu_v5e"]["classifier_name"] = "tampered"
    back = DeploymentBundle.from_blob(blob)
    assert back.devices == ["tpu_v4"]  # corrupt device dropped, not fatal
    assert [e["section"] for e in back.load_errors] == ["deployments.tpu_v5e"]
    # lookups for the dropped device recover through devices.FALLBACKS
    dep, resolved = back.deployment_for("tpu_v5e")
    assert resolved == "tpu_v4" and dep is back.deployments["tpu_v4"]


def test_v6_corrupt_family_section_drops_family_only(bundle_fam):
    clean = DeploymentBundle.from_blob(bundle_fam.to_blob())
    assert not clean.load_errors and "wkv" in clean.deployments["tpu_v5e"].families
    blob = bundle_fam.to_blob()
    blob["deployments"]["tpu_v5e"]["families"]["wkv"]["configs"] = ["garbage"]
    back = DeploymentBundle.from_blob(blob)
    # the device survives minus the corrupt family (its op serves the ref path)
    assert "tpu_v5e" in back.deployments
    assert "wkv" not in back.deployments["tpu_v5e"].families
    secs = [e["section"] for e in back.load_errors]
    assert secs == ["deployments.tpu_v5e.families.wkv"]
    assert back.deployments["tpu_v4"].configs == bundle_fam.deployments["tpu_v4"].configs


def test_v6_missing_checksummed_family_is_recorded(bundle_fam):
    blob = bundle_fam.to_blob()
    del blob["deployments"]["tpu_v5e"]["families"]["wkv"]
    back = DeploymentBundle.from_blob(blob)
    assert any(e["section"].endswith("families.wkv") and "missing" in e["error"]
               for e in back.load_errors)


def test_v6_all_devices_corrupt_raises_integrity_error(bundle2):
    blob = bundle2.to_blob()
    for name in blob["deployments"]:
        blob["deployments"][name]["device"] = "tampered"
    with pytest.raises(BundleIntegrityError, match="no deployment"):
        DeploymentBundle.from_blob(blob)


def test_v6_provenance_mismatch_dropped_not_fatal(bundle2):
    blob = bundle2.to_blob()
    blob["provenance"] = {"tpu_v5e": {"seed": 1}}
    blob["checksums"]["provenance"] = "00000000"
    back = DeploymentBundle.from_blob(blob)
    assert back.devices == ["tpu_v4", "tpu_v5e"]  # deployments unaffected
    assert any(e["section"] == "provenance" for e in back.load_errors)
    assert "seed" not in back.deployments["tpu_v5e"].meta


# ---------------------------------------------------------------------------
# codegen
# ---------------------------------------------------------------------------
def test_bundle_to_python_routes_by_device(bundle2):
    src = bundle_to_python(bundle2)
    ns = {}
    exec(src, ns)  # noqa: S102 — generated launcher code, the paper's embedding
    assert set(ns["DEVICE_SELECTORS"]) == {"tpu_v4", "tpu_v5e"}
    feats = build_model_dataset(synthetic_problems(30)).features
    for device in ("tpu_v5e", "tpu_v4"):
        want = list(bundle2.deployments[device].classifier.predict(feats))
        got = [ns["select_kernel"](device, *row) for row in feats]
        assert got == want
    # untuned device routes through the baked-in fallback chain
    want = list(bundle2.deployments["tpu_v4"].classifier.predict(feats))
    got = [ns["select_kernel"]("tpu_v5p", *row) for row in feats]
    assert got == want
    # raw jax device_kind strings canonicalize inside the generated launcher
    row = feats[0]
    assert ns["select_kernel"]("TPU v4", *row) == ns["select_kernel"]("tpu_v4", *row)
    assert ns["select_kernel"]("TPU v5 lite", *row) == ns["select_kernel"]("tpu_v5e", *row)
