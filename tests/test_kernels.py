"""Per-kernel allclose vs the ref.py oracles, sweeping shapes/dtypes/configs.

All Pallas kernels run in interpret=True (kernel body executed in Python on
CPU) — the TPU path differs only in lowering, not semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.attention import (
    AttentionConfig,
    attention_config_space,
    flash_attention_pallas,
)
from repro.kernels.matmul import DEFAULT_CONFIG, MatmulConfig, config_space, matmul_pallas
from repro.kernels.ref import flash_attention_ref, matmul_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _mm_case(m, k, n, dtype, cfg):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + n * 3 + k))
    a = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (k, n), jnp.float32).astype(dtype)
    got = matmul_pallas(a, b, cfg, interpret=True)
    want = matmul_ref(a, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    tol = dict(TOL[dtype])
    if dtype == jnp.float32:
        # deep k spans multiple block_k tiles: the per-tile accumulation
        # order differs from one fused dot, so abs error grows with k
        tol["atol"] = max(tol["atol"], 3e-8 * k)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol
    )


# -- shape sweep (block-aligned, ragged, tiny, tall-skinny, deep-k) ----------
@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),
        (256, 512, 128),
        (100, 130, 260),  # ragged everywhere
        (1, 512, 384),  # decode GEMV
        (8, 4096, 128),  # tall-skinny deep-k
        (130, 100, 70),  # n < 128 (lane padding)
        (512, 128, 512),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(m, k, n, dtype):
    _mm_case(m, k, n, dtype, DEFAULT_CONFIG)


# -- config sweep on a fixed ragged shape ------------------------------------
@pytest.mark.parametrize("cfg_idx", range(0, len(config_space()), 23))
def test_matmul_config_sweep(cfg_idx):
    cfg = config_space()[cfg_idx]
    _mm_case(120, 260, 200, jnp.float32, cfg)


def test_matmul_orders_agree():
    for order in ("mnk", "nmk"):
        _mm_case(64, 256, 256, jnp.float32, MatmulConfig(32, 128, 128, order))


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((4, 8, 16))
    with pytest.raises(ValueError):
        matmul_pallas(a, jnp.zeros((16, 4)), DEFAULT_CONFIG, interpret=True)
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((4, 8)), jnp.zeros((16, 4)), DEFAULT_CONFIG, interpret=True)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    cfg_i=st.integers(0, len(config_space()) - 1),
)
def test_matmul_property(m, k, n, cfg_i):
    """Property: every (shape, config) cell matches the oracle."""
    _mm_case(m, k, n, jnp.float32, config_space()[cfg_i])


def test_config_space_validity():
    space = config_space()
    assert len(space) > 100  # a real tuning space
    for cfg in space:
        assert cfg.is_valid()
        assert cfg.block_n % 128 == 0 and cfg.block_k % 128 == 0
    assert len(set(space)) == len(space)
    rt = MatmulConfig.from_dict(space[5].to_dict())
    assert rt == space[5]


# -- attention ----------------------------------------------------------------
def _attn_case(sq, skv, d, causal, cfg, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(sq * 5 + skv), 3)
    q = jax.random.normal(ks[0], (sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (skv, d), jnp.float32).astype(dtype)
    got = flash_attention_pallas(q, k, v, cfg, causal=causal, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("sq,skv", [(128, 128), (70, 200), (256, 256), (1, 300), (33, 33)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_shapes(sq, skv, causal):
    _attn_case(sq, skv, 64, causal, AttentionConfig(128, 128))


@pytest.mark.parametrize("cfg", attention_config_space()[::3])
def test_attention_config_sweep(cfg):
    _attn_case(200, 200, 64, True, cfg)


def test_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (64, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (128, 64), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, AttentionConfig(128, 128), causal=True, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )
