"""Fleet bootstrap + extra property tests on pipeline invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.normalize import NORMALIZATIONS, normalize
from repro.data.pipeline import DataConfig
from repro.launch.fleet import FleetTopology, fleet_data_config, topology_from_env


# ---------------------------------------------------------------------------
# fleet topology
# ---------------------------------------------------------------------------
def test_topology_from_env_defaults():
    t = topology_from_env({})
    assert t.num_processes == 1 and t.process_id == 0 and not t.is_multihost


def test_topology_from_env_explicit():
    t = topology_from_env(
        {"REPRO_COORDINATOR": "10.0.0.1:9999", "REPRO_NUM_PROCESSES": "64", "REPRO_PROCESS_ID": "7"}
    )
    assert t == FleetTopology("10.0.0.1:9999", 64, 7)
    assert t.is_multihost


def test_topology_from_slurm_env():
    t = topology_from_env(
        {"SLURM_LAUNCH_NODE_IPADDR": "10.0.0.2", "SLURM_NTASKS": "8", "SLURM_PROCID": "3"}
    )
    assert t.coordinator == "10.0.0.2:12355"
    assert (t.num_processes, t.process_id) == (8, 3)


def test_topology_bad_pid():
    with pytest.raises(ValueError):
        topology_from_env({"REPRO_NUM_PROCESSES": "4", "REPRO_PROCESS_ID": "4"})


def test_fleet_data_config():
    base = DataConfig(global_batch=256, seq_len=128)
    t = FleetTopology("x:1", 32, 5)
    d = fleet_data_config(base, t)
    assert d.host_index == 5 and d.host_count == 32 and d.local_batch == 8
    with pytest.raises(ValueError):
        fleet_data_config(DataConfig(global_batch=10), FleetTopology("x:1", 3, 0))


# ---------------------------------------------------------------------------
# property tests: normalization invariants (paper §3.4)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    st.integers(0, 10_000),
    st.floats(0.1, 1000.0),
    st.sampled_from(NORMALIZATIONS),
)
def test_normalize_scale_invariance(seed, scale, method):
    """Normalization depends only on *relative* performance: f(c·x) == f(x)."""
    rng = np.random.default_rng(seed)
    perf = rng.uniform(0, 100, size=(6, 20))
    np.testing.assert_allclose(
        normalize(perf * scale, method), normalize(perf, method), rtol=1e-9, atol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(NORMALIZATIONS))
def test_normalize_argmax_preserved(seed, method):
    """The best config per problem stays the argmax after normalization."""
    rng = np.random.default_rng(seed)
    perf = rng.uniform(0.1, 100, size=(5, 15))
    out = normalize(perf, method)
    for i in range(5):
        assert out[i, perf[i].argmax()] == out[i].max()
