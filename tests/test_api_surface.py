"""Public-API snapshot: accidental surface breaks fail CI, deliberate ones
update the frozen lists below (and the README migration map if a legacy name
moves).

The snapshot covers the four entry layers of the redesigned API:
``repro`` (the facade), ``repro.core`` (the tuning pipeline),
``repro.kernels.ops`` (dispatch + the deprecated global shims),
``repro.core.faults`` (the failure-containment layer, which also absorbed
the former ``repro.ft.runtime`` training-side fault-tolerance helpers),
``repro.serve`` (the fleet serving tier: paged KV pool, scheduler, router),
and ``repro.control`` (the tuning control plane: job service, artifact
registry, telemetry federation).
"""
import importlib

import pytest

REPRO_ALL = [
    "ArtifactRegistry",
    "ControlPlane",
    "ControlPlaneClient",
    "Deployment",
    "DeploymentBundle",
    "EngineStatus",
    "FaultPlan",
    "KernelRuntime",
    "PolicySubscriber",
    "Request",
    "Router",
    "ServingEngine",
    "TelemetrySnapshot",
    "Ticket",
    "__version__",
    "current_runtime",
    "default_runtime",
    "install_bundle",
    "load_bundle",
    "reset_default_runtime",
    "tune",
]

CORE_ALL = [
    "CLASSIFIERS",
    "CLUSTER_METHODS",
    "NORMALIZATIONS",
    "PCA",
    "Deployment",
    "DeploymentBundle",
    "FamilyPipelineResult",
    "FamilyTuning",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "FlatTree",
    "FleetTuneResult",
    "KernelFamily",
    "KernelRuntime",
    "TelemetrySnapshot",
    "TransferPrior",
    "TuneResult",
    "TuningDataset",
    "achievable_fraction",
    "build_family_dataset",
    "build_model_dataset",
    "canonical_device_name",
    "classifier_fraction",
    "current_runtime",
    "default_runtime",
    "detect_device",
    "evaluate_methods",
    "families",
    "family_names",
    "get_family",
    "harvest_problems",
    "install_bundle",
    "make_classifier",
    "normalize",
    "problem_features",
    "register_family",
    "reset_default_runtime",
    "resolve_device",
    "run_family_pipeline",
    "save_fleet",
    "select_configs",
    "select_from_dataset",
    "synthetic_problems",
    "train_deployment",
    "tune",
    "tune_dataset",
    "tune_family",
    "tune_fleet",
    "tune_for_archs",
]

OPS_ALL = [
    "KernelPolicy",
    "FixedPolicy",
    "attention",
    "matmul",
    "ssm_scan",
    "wkv",
    "select_kernel_config",
    "select_matmul_config",
    "select_ssm_config",
    "select_wkv_config",
    "active_device",
    "device_policies",
    "device_resolution",
    "get_kernel_policy",
    "policy_epoch",
    "selection_log",
    "selection_logging_enabled",
    "shape_cache_stats",
    "activate_device",
    "clear_device_policies",
    "clear_selection_log",
    "clear_shape_cache",
    "set_kernel_policy",
    "set_kernel_policy_for_device",
    "set_pallas_enabled",
    "set_selection_logging",
    "set_shape_cache_cap",
]

SERVE_ALL = [
    "EngineStatus",
    "KVPool",
    "Objective",
    "Request",
    "RetuneEvent",
    "Router",
    "Scheduler",
    "SchedulerConfig",
    "ServingEngine",
    "Ticket",
]

CONTROL_ALL = [
    "ArtifactRegistry",
    "ArtifactVersion",
    "ControlPlane",
    "ControlPlaneClient",
    "ControlPlaneError",
    "Job",
    "PolicySubscriber",
    "content_version",
]

FAULTS_ALL = [
    "FAULT_KINDS",
    "ElasticPlan",
    "FaultError",
    "FaultEvent",
    "FaultPlan",
    "FaultSpec",
    "GUARDED_EXCEPTIONS",
    "InjectedCompileError",
    "InjectedOOMError",
    "NonFiniteOutputError",
    "PreemptionGuard",
    "StragglerDetector",
    "elastic_plan",
    "incident",
]


@pytest.mark.parametrize(
    "module,snapshot",
    [
        ("repro", REPRO_ALL),
        ("repro.core", CORE_ALL),
        ("repro.kernels.ops", OPS_ALL),
        ("repro.core.faults", FAULTS_ALL),
        ("repro.serve", SERVE_ALL),
        ("repro.control", CONTROL_ALL),
    ],
    ids=["repro", "repro.core", "repro.kernels.ops", "repro.core.faults",
         "repro.serve", "repro.control"],
)
def test_public_surface_frozen(module, snapshot):
    mod = importlib.import_module(module)
    assert sorted(mod.__all__) == sorted(snapshot), (
        f"{module}.__all__ changed — if deliberate, update tests/test_api_surface.py "
        f"(and the README migration map for legacy names)"
    )
    assert len(set(snapshot)) == len(snapshot), "snapshot has duplicates"


@pytest.mark.parametrize(
    "module", ["repro", "repro.core", "repro.kernels.ops", "repro.serve",
               "repro.control"],
)
def test_all_names_resolve(module):
    mod = importlib.import_module(module)
    for name in mod.__all__:
        assert getattr(mod, name, None) is not None, f"{module}.{name} does not resolve"


def test_facade_version_matches_package_metadata():
    import repro

    assert isinstance(repro.__version__, str) and repro.__version__.count(".") == 2


def test_facade_lazy_names_complete():
    """Every __all__ name is either defined eagerly or wired into _LAZY."""
    import repro

    eager = {"__version__", "tune", "load_bundle"}
    assert set(repro.__all__) == eager | set(repro._LAZY)
