"""Measured-CPU benchmark source: blocked GEMM correctness + dataset sanity."""
import numpy as np
import pytest

from repro.core.cpubench import blocked_gemm, build_cpu_dataset, cpu_problems
from repro.kernels.matmul import MatmulConfig


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (100, 130, 70), (1, 256, 128)])
@pytest.mark.parametrize("order", ["mnk", "nmk"])
def test_blocked_gemm_matches_dot(m, k, n, order, rng):
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    cfg = MatmulConfig(32, 128, 128, order)
    np.testing.assert_allclose(blocked_gemm(a, b, cfg), a @ b, rtol=1e-4, atol=1e-4)


def test_cpu_problems_deterministic():
    assert cpu_problems(8) == cpu_problems(8)
    assert len(cpu_problems(8)) == 8


def test_build_cpu_dataset_small():
    probs = [(64, 64, 64, 1), (8, 256, 128, 1)]
    cfgs = [MatmulConfig(32, 128, 128, "mnk"), MatmulConfig(64, 128, 128, "nmk")]
    ds = build_cpu_dataset(probs, cfgs)
    assert ds.perf.shape == (2, 2)
    assert np.all(ds.perf > 0)  # measured gflops/s
    assert ds.source == "measured"
