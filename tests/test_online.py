"""Online (dynamic) kernel selection vs the offline pipeline (paper §2.2)."""
import numpy as np
import pytest

from repro.core.dataset import build_model_dataset, synthetic_problems
from repro.core.online import OnlinePolicy, _bucket
from repro.core.perfmodel import TPU_V5E, predict_time
from repro.core.tuner import tune
from repro.kernels.matmul import MatmulConfig, config_space


def _model_measure(problem, cfg):
    t = predict_time(problem, cfg, TPU_V5E)
    return t if np.isfinite(t) else 1e9


def test_explore_then_commit():
    cands = list(config_space())[:5]
    pol = OnlinePolicy(_model_measure, cands, trials_per_arm=1)
    p = (512, 784, 512, 16)
    picks = [pol.select_matmul(*p) for _ in range(8)]
    # first len(cands) picks explore each arm once, then commit
    assert picks[:5] == cands
    committed = pol.committed()[_bucket(p)]
    assert all(c == committed for c in picks[5:])
    # the committed arm is the measured-fastest candidate
    best = min(cands, key=lambda c: _model_measure(p, c))
    assert committed == best
    assert pol.stats["explore"] == 5 and pol.stats["commit"] == 3
    assert pol.warmup_cost() > 0


def test_buckets_share_measurements():
    cands = list(config_space())[:3]
    pol = OnlinePolicy(_model_measure, cands)
    for _ in range(3):
        pol.select_matmul(512, 784, 512, 16)
    # a nearby shape lands in the same log2 bucket: committed immediately
    pol.select_matmul(513, 790, 520, 16)
    assert pol.stats["explore"] == 3


def test_prior_is_measured_first():
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    pol = OnlinePolicy(_model_measure, res.deployment.configs, prior=res.deployment)
    p = (64, 4096, 1024, 1)
    first = pol.select_matmul(*p)
    assert first == res.deployment.select_matmul(*p)


def test_hybrid_beats_or_matches_offline_classifier():
    """With the deployment as candidate set, online measurement can only
    improve on the classifier's picks (at a bounded warm-up cost)."""
    ds = build_model_dataset(synthetic_problems(80))
    res = tune(ds, n_kernels=6)
    dep = res.deployment
    problems = [(512, 784, 512, 16), (1, 4096, 1024, 1), (2048, 2048, 256, 4), (32, 12288, 512, 1)]
    total_online, total_offline = 0.0, 0.0
    pol = OnlinePolicy(_model_measure, dep.configs, prior=dep)
    for p in problems:
        for _ in range(len(dep.configs) + 1):
            cfg = pol.select_matmul(*p)
        total_online += _model_measure(p, cfg)  # committed pick
        total_offline += _model_measure(p, dep.select_matmul(*p))
    assert total_online <= total_offline + 1e-12


def test_select_attention_falls_back():
    pol = OnlinePolicy(_model_measure, list(config_space())[:2])
    cfg = pol.select_attention(128, 2048, 128)
    assert cfg is not None
