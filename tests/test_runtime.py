"""KernelRuntime: explicit multi-tenant runtime handles (DESIGN.md §10).

Covers the api_redesign contract:
  * activation scoping — ops dispatch against the innermost active runtime,
    falling back to the process default;
  * the legacy module-level ops API is a deprecated shim over the default
    runtime with byte-identical selections (proven on the committed v1-v5
    deployment fixtures);
  * two runtimes serving different tunings concurrently from separate
    threads share no policy, shape-cache, or selection-log state.
"""
import json
import threading
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.core.bundle import DeploymentBundle, install_bundle
from repro.core.dispatch import Deployment
from repro.core.runtime import (
    KernelRuntime,
    current_runtime,
    default_runtime,
    reset_default_runtime,
)
from repro.kernels import ops
from repro.kernels.matmul import MatmulConfig

DATA = Path(__file__).parent / "data"


@pytest.fixture(autouse=True)
def _fresh_default():
    reset_default_runtime()
    yield
    reset_default_runtime()


def _policy(bm: int) -> ops.FixedPolicy:
    return ops.FixedPolicy(matmul_config=MatmulConfig(bm, 128, 128))


@pytest.fixture(scope="module")
def tuned_pair():
    """Two real tuned deployments whose matmul selections differ."""
    from repro.core.dataset import build_model_dataset, synthetic_problems
    from repro.core.tuner import tune

    ds = build_model_dataset(synthetic_problems(60), device_name="tpu_v5e")
    a = tune(ds, n_kernels=6, families=[]).deployment
    b = tune(ds, n_kernels=2, families=[]).deployment
    assert a.configs != b.configs
    return a, b


# ---------------------------------------------------------------------------
# activation scoping
# ---------------------------------------------------------------------------
def test_current_runtime_defaults_and_scopes():
    assert current_runtime() is default_runtime()
    rt1, rt2 = KernelRuntime("one"), KernelRuntime("two")
    with rt1.activate():
        assert current_runtime() is rt1
        with rt2.activate():  # innermost wins
            assert current_runtime() is rt2
        assert current_runtime() is rt1
    assert current_runtime() is default_runtime()


def test_ops_dispatch_follows_active_runtime():
    rt1, rt2 = KernelRuntime(), KernelRuntime()
    rt1.install(_policy(64))
    rt2.install(_policy(256))
    default_runtime().install(_policy(8))
    with rt1.activate():
        assert ops.select_matmul_config(64, 64, 64).block_m == 64
        with rt2.activate():
            assert ops.select_matmul_config(64, 64, 64).block_m == 256
    assert ops.select_matmul_config(64, 64, 64).block_m == 8  # default again


def test_activation_is_per_thread():
    rt = KernelRuntime()
    rt.install(_policy(512))
    seen = {}

    def other_thread():
        seen["runtime"] = current_runtime()
        seen["cfg"] = ops.select_matmul_config(32, 32, 32)

    with rt.activate():
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
    assert seen["runtime"] is default_runtime()  # activation did not leak
    assert seen["cfg"] is None  # default runtime has no policy


def test_runtime_state_is_isolated():
    rt1, rt2 = KernelRuntime(), KernelRuntime()
    rt1.install(_policy(64))
    rt2.install(_policy(256))
    rt1.set_selection_logging(True)
    rt2.set_selection_logging(True)
    rt1.select_matmul_config(128, 128, 128)
    assert len(rt1.selection_log()) == 1 and rt2.selection_log() == []
    assert rt1.shape_cache_stats()["size"] == 1
    assert rt2.shape_cache_stats()["size"] == 0
    assert rt1.policy_epoch() == rt2.policy_epoch() == 1
    rt2.install(None)  # epoch bump in rt2 only
    assert rt1.policy_epoch() == 1 and rt2.policy_epoch() == 2


def test_shape_cache_cap_reaches_other_threads():
    """rt.set_shape_cache_cap is runtime-scoped: fresh threads adopt it."""
    rt = KernelRuntime()
    rt.install(_policy(64))
    rt.set_shape_cache_cap(3)
    seen = {}

    def worker():
        for i in range(8):
            rt.select_matmul_config(16 + i, 16, 16)
        seen.update(rt.shape_cache_stats())

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert seen["cap"] == 3 and seen["size"] == 3  # LRU-bounded, not 1024/8


def test_engine_ctor_adopts_current_runtime():
    from repro.serve.engine import ServingEngine

    class _NullModel:
        def init_cache(self, b, n):
            return {}

        def decode_step(self, params, cache, tokens, positions):
            raise NotImplementedError

    rt = KernelRuntime()
    with rt.activate():
        eng = ServingEngine(_NullModel(), params={}, max_batch=1, cache_len=8)
    assert eng.runtime is rt
    eng2 = ServingEngine(_NullModel(), params={}, max_batch=1, cache_len=8)
    assert eng2.runtime is default_runtime()


# ---------------------------------------------------------------------------
# legacy shims: deprecation + byte-identical routing
# ---------------------------------------------------------------------------
LEGACY_MUTATORS = [
    (lambda: ops.set_kernel_policy(None), "set_kernel_policy"),
    (lambda: ops.set_kernel_policy_for_device("tpu_v5e", ops.FixedPolicy()),
     "set_kernel_policy_for_device"),
    (lambda: ops.clear_device_policies(), "clear_device_policies"),
    (lambda: ops.set_pallas_enabled(False), "set_pallas_enabled"),
    (lambda: ops.set_selection_logging(False), "set_selection_logging"),
    (lambda: ops.clear_selection_log(), "clear_selection_log"),
    (lambda: ops.clear_shape_cache(), "clear_shape_cache"),
    (lambda: ops.set_shape_cache_cap(512), "set_shape_cache_cap"),
]


@pytest.mark.parametrize("call,name", LEGACY_MUTATORS, ids=[n for _, n in LEGACY_MUTATORS])
def test_legacy_mutators_warn(call, name):
    with pytest.warns(DeprecationWarning, match=name):
        call()


def test_legacy_activate_device_warns():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ops.set_kernel_policy_for_device("tpu_v5e", ops.FixedPolicy())
    with pytest.warns(DeprecationWarning, match="activate_device"):
        ops.activate_device("tpu_v5e")


def test_legacy_mutators_route_to_default_runtime():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ops.set_kernel_policy(_policy(32))
        ops.set_selection_logging(True)
    rt = default_runtime()
    assert rt.policy().matmul_config.block_m == 32
    assert rt.selection_logging_enabled()
    assert ops.select_matmul_config(16, 16, 16).block_m == 32
    assert rt.selection_log() == [("matmul", (16, 16, 16, 1), _policy(32).matmul_config)]
    assert ops.shape_cache_stats() == rt.shape_cache_stats()
    assert ops.get_kernel_policy() is rt.policy()


# ---------------------------------------------------------------------------
# byte-identical selections on the committed v1-v5 fixtures
# ---------------------------------------------------------------------------
def _expected():
    return json.loads((DATA / "expected_selections.json").read_text())


@pytest.mark.parametrize("fixture", ["dep_v1.json", "dep_v2.json"])
def test_legacy_shim_selections_match_fixtures(fixture):
    """ops.* (default-runtime shim) == KernelRuntime handle == committed bytes."""
    exp = _expected()
    dep = Deployment.load(DATA / fixture)
    want = exp["devices"]["tpu_v5e"]["matmul"]

    rt = KernelRuntime()
    rt.install(dep)
    via_handle = [rt.select_matmul_config(*p).to_dict() for p in exp["matmul_probes"]]

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ops.set_kernel_policy(dep)
    via_legacy = [ops.select_matmul_config(*p).to_dict() for p in exp["matmul_probes"]]

    assert via_handle == want
    assert via_legacy == want


@pytest.mark.parametrize("fixture", ["bundle_v3.json", "bundle_v4.json"])
def test_bundle_runtime_selections_match_fixtures(fixture):
    """bundle.runtime(device=...) serves the committed per-device selections."""
    exp = _expected()
    bundle = DeploymentBundle.load(DATA / fixture)
    for device, want in exp["devices"].items():
        rt = bundle.runtime(device=device)
        assert rt.active_device() == device
        got_m = [rt.select_matmul_config(*p).to_dict() for p in exp["matmul_probes"]]
        got_a = [rt.select_config("attention", p).to_dict() for p in exp["attention_probes"]]
        assert got_m == want["matmul"], device
        assert got_a == want["attention"], device
        # legacy install_bundle into the default runtime: same bytes
        install_bundle(bundle, device=device)
        got_legacy = [ops.select_matmul_config(*p).to_dict() for p in exp["matmul_probes"]]
        assert got_legacy == want["matmul"], device


def test_install_bundle_targets_explicit_runtime():
    bundle = DeploymentBundle.load(DATA / "bundle_v4.json")
    rt = KernelRuntime()
    dep = install_bundle(bundle, device="tpu_v4", runtime=rt)
    assert rt.active_device() == "tpu_v4"
    assert dep is bundle.deployments["tpu_v4"]
    assert default_runtime().active_device() is None  # untouched


# ---------------------------------------------------------------------------
# concurrent runtimes: two tunings serving from separate threads, zero
# cross-talk (the multi-tenant acceptance criterion)
# ---------------------------------------------------------------------------
def test_concurrent_runtimes_no_cross_talk(tuned_pair):
    dep_a, dep_b = tuned_pair
    rt_a, rt_b = KernelRuntime("tenant-a"), KernelRuntime("tenant-b")
    rt_a.install(dep_a)
    rt_b.install(dep_b)
    rt_a.set_selection_logging(True)
    rt_b.set_selection_logging(True)

    probes = [(512, 784, 512, 16), (1, 4096, 512, 1), (2048, 2048, 2048, 1),
              (64, 512, 64, 4), (4096, 128, 4096, 1)]
    n_rounds = 200
    errors: list[str] = []
    barrier = threading.Barrier(2)

    def worker(rt: KernelRuntime, dep: Deployment, tag: str):
        try:
            barrier.wait(timeout=10)
            with rt.activate():
                for i in range(n_rounds):
                    p = probes[i % len(probes)]
                    got = ops.select_matmul_config(*p)
                    want = dep.select_matmul(*p)
                    if got != want:
                        errors.append(f"{tag}: {p} -> {got}, want {want}")
                        return
        except Exception as e:  # pragma: no cover - surfaced via errors
            errors.append(f"{tag}: {e!r}")

    ta = threading.Thread(target=worker, args=(rt_a, dep_a, "a"))
    tb = threading.Thread(target=worker, args=(rt_b, dep_b, "b"))
    ta.start(); tb.start(); ta.join(); tb.join()
    assert not errors, errors

    # every logged selection belongs to the runtime's own deployment
    log_a, log_b = rt_a.selection_log(), rt_b.selection_log()
    assert len(log_a) == len(log_b) == n_rounds
    assert all(cfg in dep_a.configs for _, _, cfg in log_a)
    assert all(cfg in dep_b.configs for _, _, cfg in log_b)
    # shape caches stayed per-runtime (the worker thread's locals, but the
    # stats read from this thread must also show zero leakage into default)
    assert default_runtime().shape_cache_stats()["size"] == 0
    assert default_runtime().selection_log() == []


def test_concurrent_hot_swap_isolated(tuned_pair):
    """A retune-style hot swap in tenant A never invalidates tenant B."""
    dep_a, dep_b = tuned_pair
    rt_a, rt_b = KernelRuntime(), KernelRuntime()
    rt_a.install_for_device("tpu_v5e", dep_a)
    rt_a.activate_device("tpu_v5e")
    rt_b.install_for_device("tpu_v5e", dep_b)
    rt_b.activate_device("tpu_v5e")

    stop = threading.Event()
    errors: list[str] = []

    def swapper():
        for _ in range(50):
            rt_a.install_for_device("tpu_v5e", dep_a)  # epoch bump in A only
        stop.set()

    def reader():
        epoch0 = rt_b.policy_epoch()
        while not stop.is_set():
            cfg = rt_b.select_matmul_config(512, 784, 512, 16)
            if cfg != dep_b.select_matmul(512, 784, 512, 16):
                errors.append(f"B served {cfg}")
                return
        if rt_b.policy_epoch() != epoch0:
            errors.append("B's epoch moved during A's swaps")

    ts, tr = threading.Thread(target=swapper), threading.Thread(target=reader)
    tr.start(); ts.start(); ts.join(); tr.join()
    assert not errors, errors
    assert rt_a.policy_epoch() > rt_b.policy_epoch()
    # B's warm shape cache survived all of A's swaps (no spurious resync)
    assert rt_b.select_matmul_config(512, 784, 512, 16) == dep_b.select_matmul(512, 784, 512, 16)


def test_two_engines_two_runtimes_one_process(tuned_pair):
    """Engine-level multi-tenancy: different bundles, same thread, no leaks."""
    from repro.serve.engine import Request, ServingEngine

    dep_a, dep_b = tuned_pair
    bundle_a = DeploymentBundle({"tpu_v5e": dep_a})
    bundle_b = DeploymentBundle({"tpu_v5e": dep_b})
    rt_a = bundle_a.runtime(device="tpu_v5e", name="tenant-a")
    rt_b = bundle_b.runtime(device="tpu_v5e", name="tenant-b")
    rt_a.set_selection_logging(True)
    rt_b.set_selection_logging(True)

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models.model import build_model

    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    eng_a = rt_a.serve(model, params, max_batch=1, cache_len=64)
    eng_b = ServingEngine(model, params, max_batch=1, cache_len=64, runtime=rt_b)
    assert eng_a.runtime is rt_a and eng_b.runtime is rt_b

    rng = np.random.default_rng(0)
    reqs_a = [Request(uid=0, prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                      max_new_tokens=4)]
    reqs_b = [Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                      max_new_tokens=4)]
    assert eng_a.run(reqs_a).completed == 1
    assert eng_b.run(reqs_b).completed == 1

    sel_a = {cfg_ for _, _, cfg_ in rt_a.selection_log()}
    sel_b = {cfg_ for _, _, cfg_ in rt_b.selection_log()}
    assert sel_a and sel_a <= set(dep_a.configs)
    assert sel_b and sel_b <= set(dep_b.configs)
    assert default_runtime().selection_log() == []  # nothing global leaked
    assert default_runtime().active_device() is None
