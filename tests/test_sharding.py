"""Sharding-rule unit tests (no 512-device mesh needed — pspecs are pure)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.dist import sharding as shd
from repro.models.model import build_model
from repro.optim import adamw


class _FakeMesh:
    axis_names = ("pod", "data", "model")


class _FakeMeshSingle:
    axis_names = ("data", "model")


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "rwkv6-7b", "hymba-1.5b", "seamless-m4t-large-v2", "llama-3.2-vision-90b"])
def test_param_pspecs_cover_all_leaves(arch):
    cfg = registry.get(arch).reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(params, _FakeMeshSingle())
    leaves_p = jax.tree_util.tree_leaves_with_path(params)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for (path, leaf), spec in zip(leaves_p, leaves_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        # sharded dims must name mesh axes that exist
        for ax in spec:
            if ax is not None:
                axes = (ax,) if isinstance(ax, str) else ax
                for a in axes:
                    assert a in ("data", "model", "pod")


def test_big_gemm_weights_are_tp_sharded():
    cfg = registry.get("phi4-mini-3.8b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(params, _FakeMeshSingle())
    attn = specs["blocks"]["attn"]
    assert attn["wq"] == P(None, "data", "model")  # col-parallel + fsdp
    assert attn["wo"] == P(None, "model", "data")  # row-parallel
    emb = specs["emb"]
    assert emb["embed"] == P("model", "data")


def test_moe_experts_ep_sharded():
    cfg = registry.get("qwen3-moe-235b-a22b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(params, _FakeMeshSingle())
    moe = specs["blocks"]["moe"]
    w_up = moe["w_up"]
    assert w_up[1] == "model"  # experts over the model axis (EP)


def test_batch_pspecs_dp_and_sp():
    mesh = _FakeMesh()
    tree = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    dp = shd.batch_pspecs(tree, mesh)
    assert dp["tokens"] == P(("pod", "data"), None)
    sp = shd.batch_pspecs(tree, mesh, shard_seq=True)
    assert sp["tokens"] == P(None, "data")


def test_cache_pspecs():
    mesh = _FakeMeshSingle()
    cache = {
        "k": jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16),
        "wkv": jax.ShapeDtypeStruct((4, 2, 8, 16, 16), jnp.float32),
    }
    spec = shd.cache_pspecs(cache, mesh)
    assert spec["k"] == P(None, ("data",), None, None, None)
    sp = shd.cache_pspecs(cache, mesh, shard_seq=True)
    assert sp["k"] == P(None, None, "data", None, None)  # context-parallel
    assert sp["wkv"] == P(None, None, "model", None, None)


def test_opt_pspecs_match_params():
    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec = shd.param_pspecs(params, _FakeMeshSingle())
    opt = jax.eval_shape(adamw.init, params)
    ospec = shd.opt_pspecs(opt, pspec)
    assert ospec.step == P()
    assert jax.tree.structure(ospec.m, is_leaf=lambda x: isinstance(x, P)) == jax.tree.structure(
        pspec, is_leaf=lambda x: isinstance(x, P)
    )
