"""Unit tests for the paper pipeline: normalize, PCA, clustering, classifiers."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.classify import CLASSIFIERS, DecisionTreeClassifier, make_classifier
from repro.core.cluster import (
    CLUSTER_METHODS,
    density_labels,
    kmeans,
    regression_tree_leaves,
    select_configs,
    spectral_labels,
)
from repro.core.normalize import NORMALIZATIONS, normalize
from repro.core.pca import PCA


# ---------------------------------------------------------------------------
# normalization (paper §3.4)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", NORMALIZATIONS)
def test_normalize_range_and_best(method, rng):
    perf = rng.uniform(0, 3000, size=(40, 64))
    out = normalize(perf, method)
    assert out.shape == perf.shape
    assert np.all(out >= 0) and np.all(out <= 1)
    # The per-row best config survives near 1 in every scheme.
    best = out[np.arange(40), perf.argmax(1)]
    assert np.all(best >= 0.5)


@pytest.mark.parametrize("method", NORMALIZATIONS)
def test_normalize_zero_rows(method):
    perf = np.zeros((3, 10))
    assert np.all(normalize(perf, method) == 0)


def test_normalize_cutoff_sparsity(rng):
    perf = rng.uniform(0, 100, size=(30, 50))
    raw = normalize(perf, "raw_cutoff")
    std = normalize(perf, "standard")
    # clamps exactly the sub-cutoff entries, preserves the rest
    assert np.all(raw[std < 0.9] == 0)
    np.testing.assert_allclose(raw[std >= 0.9], std[std >= 0.9])
    # rescaled cutoff spans [0, 1]
    cut = normalize(perf, "cutoff")
    assert cut.max() <= 1.0 and np.isclose(cut.max(), 1.0)


def test_normalize_sigmoid_midpoint():
    perf = np.array([[0.85, 1.0, 0.79, 0.5]])
    out = normalize(perf, "sigmoid")
    assert np.isclose(out[0, 0], 0.5, atol=1e-6)  # 85% -> 0.5 (paper)
    assert out[0, 2] < 0.1  # <80% -> <0.1
    assert out[0, 3] < 1e-3


def test_normalize_unknown():
    with pytest.raises(ValueError):
        normalize(np.ones((2, 2)), "nope")


# ---------------------------------------------------------------------------
# PCA (paper §3.3)
# ---------------------------------------------------------------------------
def test_pca_variance_and_reconstruction(rng):
    # Low-rank data + noise: few components explain most variance.
    base = rng.normal(size=(100, 3)) @ rng.normal(size=(3, 40))
    x = base + 0.01 * rng.normal(size=(100, 40))
    p = PCA().fit(x)
    ratio = p._full_ratio
    assert np.isclose(ratio.sum(), 1.0)
    assert np.all(np.diff(ratio) <= 1e-12)  # sorted descending
    assert ratio[:3].sum() > 0.95
    assert p.n_components_for_variance(0.95) <= 3
    p4 = PCA(n_components=3)
    z = p4.fit_transform(x)
    assert z.shape == (100, 3)
    np.testing.assert_allclose(p4.inverse_transform(z), x, atol=0.5)


def test_pca_transform_before_fit():
    with pytest.raises(RuntimeError):
        PCA().transform(np.ones((2, 2)))


# ---------------------------------------------------------------------------
# clustering (paper §4.1)
# ---------------------------------------------------------------------------
def _blobs(rng, k=4, n_per=20, d=8, spread=0.05):
    centers = rng.normal(size=(k, d)) * 3
    x = np.concatenate([c + spread * rng.normal(size=(n_per, d)) for c in centers])
    y = np.repeat(np.arange(k), n_per)
    return x, y


def _label_agreement(a, b):
    """Fraction of pairs on which two labelings agree (Rand index)."""
    same_a = a[:, None] == a[None, :]
    same_b = b[:, None] == b[None, :]
    return (same_a == same_b).mean()


@pytest.mark.parametrize(
    "fn",
    [
        lambda x, k: kmeans(x, k)[0],
        lambda x, k: spectral_labels(x, k),
        lambda x, k: density_labels(x, k),
    ],
    ids=["kmeans", "spectral", "density"],
)
def test_clustering_recovers_blobs(fn, rng):
    x, y = _blobs(rng)
    labels = fn(x, 4)
    assert labels.shape == y.shape
    assert _label_agreement(labels, y) > 0.95


def test_regression_tree_leaves(rng):
    feats = rng.uniform(0, 10, size=(60, 3))
    # perf vector depends on whether feature0 > 5 (two regimes)
    perf = np.where(feats[:, :1] > 5, rng.uniform(0.8, 1.0, (60, 6)), rng.uniform(0, 0.2, (60, 6)))
    labels = regression_tree_leaves(feats, perf, max_leaves=2)
    assert labels.max() + 1 == 2
    regime = (feats[:, 0] > 5).astype(int)
    assert _label_agreement(labels, regime) > 0.95


@pytest.mark.parametrize("method", CLUSTER_METHODS)
def test_select_configs_all_methods(method, rng):
    perf = normalize(rng.uniform(0, 100, size=(50, 30)), "standard")
    feats = rng.uniform(0, 14, size=(50, 6))
    chosen = select_configs(perf, 6, method, features=feats)
    assert len(chosen) == 6
    assert len(set(chosen)) == 6
    assert all(0 <= c < 30 for c in chosen)


def test_select_configs_unknown():
    with pytest.raises(ValueError):
        select_configs(np.ones((5, 5)), 2, "nope")


def test_tree_selection_needs_features():
    with pytest.raises(ValueError):
        select_configs(np.ones((5, 5)), 2, "tree")


# ---------------------------------------------------------------------------
# classifiers (paper §5)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(CLASSIFIERS))
def test_classifier_learns_separable(name, rng):
    x, y = _blobs(rng, k=3, n_per=30, d=4, spread=0.2)
    clf = make_classifier(name)
    clf.fit(x, y)
    acc = (clf.predict(x) == y).mean()
    assert acc > 0.9, f"{name}: {acc}"


def test_decision_tree_depth_limits(rng):
    x = rng.normal(size=(200, 5))
    y = rng.integers(0, 4, size=200)
    a = DecisionTreeClassifier().fit(x, y)
    b = DecisionTreeClassifier(max_depth=6, min_samples_leaf=3).fit(x, y)
    c = DecisionTreeClassifier(max_depth=3, min_samples_leaf=4).fit(x, y)
    assert b.depth() <= 6 and c.depth() <= 3
    assert a.depth() >= b.depth() >= c.depth()


def test_make_classifier_unknown():
    with pytest.raises(ValueError):
        make_classifier("nope")


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(10, 40), st.integers(0, 1000))
def test_tree_predict_is_total(k, n, seed):
    """Property: a fitted tree classifies any input to a valid class."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3))
    y = rng.integers(0, k, size=n)
    clf = DecisionTreeClassifier(max_depth=4).fit(x, y)
    pred = clf.predict(rng.normal(size=(50, 3)) * 10)
    assert np.all((pred >= 0) & (pred < k))
