"""Serving-engine regression tests: slot scatter, extras, truncation, starvation.

A deterministic toy model (echo+1 language model with an inspectable cache)
isolates the engine's bookkeeping from real model math; one real-model test
pins the max_batch=1 prefill-cache regression end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import EngineStatus, Request, ServingEngine, _scatter_slot


class ToyModel:
    """Echo+1 LM: next token = (last token + 1) % vocab; cache records tokens.

    Cache has both batch-leading ("k": (B, L)) and layer-leading
    ("mem": (2, B, 4)) leaves, matching the real models' two layouts.
    """

    vocab = 17

    def __init__(self):
        self.seen_extras: dict[str, tuple] = {}

    def init_cache(self, b, cache_len):
        return {
            "k": jnp.zeros((b, cache_len), jnp.float32),
            "mem": jnp.zeros((2, b, 4), jnp.float32),
        }

    def prefill(self, params, batch, cache_len):
        tokens = batch["tokens"]
        for k, v in batch.items():
            if k != "tokens":
                self.seen_extras[k] = tuple(v.shape)
        b, s = tokens.shape
        cache = self.init_cache(b, cache_len)
        cache["k"] = cache["k"].at[:, :s].set(tokens.astype(jnp.float32))
        cache["mem"] = cache["mem"] + 1.0
        logits = jax.nn.one_hot((tokens[:, -1:] + 1) % self.vocab, self.vocab)
        return logits, cache

    def decode_step(self, params, cache, tokens, positions):
        b = tokens.shape[0]
        cache = dict(cache)
        cache["k"] = cache["k"].at[jnp.arange(b), positions].set(
            tokens[:, 0].astype(jnp.float32)
        )
        logits = jax.nn.one_hot((tokens + 1) % self.vocab, self.vocab)
        return logits, cache


def _engine(**kw):
    kw.setdefault("max_batch", 1)
    kw.setdefault("cache_len", 32)
    kw.setdefault("prefill_buckets", (8, 16))
    model = ToyModel()
    return ServingEngine(model, params={}, **kw), model


# ---------------------------------------------------------------------------
# _scatter_slot
# ---------------------------------------------------------------------------
def test_scatter_slot_writes_when_pool_is_batch_one():
    """max_batch == 1: pool and prefill shapes coincide; the write must land."""
    full = jnp.zeros((1, 8))
    one = jnp.arange(8.0).reshape(1, 8)
    out = _scatter_slot(full, one, slot=0, max_batch=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(one))
    # layer-leading (L, B, ...) layout too
    full2 = jnp.zeros((2, 1, 4))
    one2 = jnp.ones((2, 1, 4))
    out2 = _scatter_slot(full2, one2, slot=0, max_batch=1)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(one2))


def test_scatter_slot_multi_batch_and_replicated():
    full = jnp.zeros((4, 6))
    one = jnp.ones((1, 6))
    out = np.asarray(_scatter_slot(full, one, slot=2, max_batch=4))
    assert out[2].sum() == 6 and out[[0, 1, 3]].sum() == 0
    # replicated leaf (no batch-1 axis in the prefill output): kept as-is
    rep_full = jnp.full((3, 5), 7.0)
    rep_one = jnp.zeros((3, 5))
    out = _scatter_slot(rep_full, rep_one, slot=1, max_batch=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rep_full))


def test_prefill_cache_lands_in_slot_when_max_batch_is_one():
    """Regression: the admit write used to be silently dropped at max_batch=1."""
    eng, _ = _engine(max_batch=1)
    prompt = np.array([3, 1, 4, 1, 5], dtype=np.int32)
    eng._admit(Request(uid=0, prompt=prompt), slot=0)
    got = np.asarray(eng.cache["k"])[0, :8]
    want = np.zeros(8)
    want[-5:] = prompt  # left-padded into the 8-bucket
    np.testing.assert_array_equal(got, want)
    assert np.asarray(eng.cache["mem"]).sum() > 0  # layer-leading leaf written too


def test_max_batch_one_matches_larger_pool_real_model():
    """Same request must decode identically in a 1-slot and a 2-slot pool."""
    from repro.configs import registry
    from repro.models.model import build_model

    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    outputs = []
    for max_batch in (1, 2):
        eng = ServingEngine(model, params, max_batch=max_batch, cache_len=64)
        req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=6)
        status = eng.run([req])
        assert status.completed == 1 and req.done
        outputs.append(req.output)
    assert outputs[0] == outputs[1]


# ---------------------------------------------------------------------------
# extra inputs
# ---------------------------------------------------------------------------
def test_admit_extras_batched_and_unbatched():
    extras = {
        "batched": jnp.ones((1, 5, 3)),   # leading batch-1 axis: pass through
        "unbatched": jnp.ones((5, 3)),    # per-sequence: gains the batch axis
        "scalar": jnp.asarray(2.0),       # scalar: becomes (1,)
    }
    eng, model = _engine(extra_inputs=extras)
    eng._admit(Request(uid=0, prompt=np.array([1, 2], dtype=np.int32)), slot=0)
    assert model.seen_extras["batched"] == (1, 5, 3)
    assert model.seen_extras["unbatched"] == (1, 5, 3)
    assert model.seen_extras["scalar"] == (1,)


# ---------------------------------------------------------------------------
# over-long prompts
# ---------------------------------------------------------------------------
def test_overlong_prompt_truncates_sliding_window():
    eng, _ = _engine()  # largest bucket = 16
    prompt = np.arange(1, 41, dtype=np.int32)  # 40 tokens, no zeros
    req = Request(uid=0, prompt=prompt, max_new_tokens=2)
    status = eng.run([req])  # must not raise
    assert status.completed == 1 and req.done
    assert req.truncated_tokens == 40 - 16
    # the last 16 prompt tokens were prefilled (sliding window keeps the tail)
    np.testing.assert_array_equal(np.asarray(eng.cache["k"])[0, :16], prompt[-16:])
    # echo+1 model: first generated token continues from the *last* prompt token
    assert req.output[0] == (int(prompt[-1]) + 1) % ToyModel.vocab


def test_fitting_prompt_not_marked_truncated():
    eng, _ = _engine()
    req = Request(uid=0, prompt=np.array([1, 2, 3], dtype=np.int32), max_new_tokens=2)
    eng.run([req])
    assert req.truncated_tokens == 0


# ---------------------------------------------------------------------------
# starvation / step budget
# ---------------------------------------------------------------------------
def test_run_marks_starved_and_in_flight_on_budget_exhaustion():
    eng, _ = _engine(max_batch=1)
    reqs = [
        Request(uid=i, prompt=np.array([1, 2], dtype=np.int32), max_new_tokens=50)
        for i in range(3)
    ]
    status = eng.run(reqs, max_steps=3)
    assert isinstance(status, EngineStatus) and status.exhausted
    assert status.completed == 0 and status.in_flight == 1 and status.queued == 2
    # the in-flight request has partial output but is NOT a completed result
    assert reqs[0].state == "active" and not reqs[0].done and reqs[0].output
    # queued requests are distinguishable from both active and done
    assert all(r.state == "starved" and not r.done for r in reqs[1:])


def test_run_completion_status():
    eng, _ = _engine(max_batch=1)
    reqs = [
        Request(uid=i, prompt=np.array([1, 2, 3], dtype=np.int32), max_new_tokens=3)
        for i in range(2)
    ]
    status = eng.run(reqs)
    assert not status.exhausted
    assert status.completed == 2 and status.in_flight == 0 and status.queued == 0
    assert all(r.done and r.state == "done" for r in reqs)
    # echo+1 chain: each new token is prev+1
    for r in reqs:
        assert r.output == [4, 5, 6]


# ---------------------------------------------------------------------------
# preemption accounting (block pressure)
# ---------------------------------------------------------------------------
def test_preemption_accounting_counts_each_request_once():
    """A preempted waiter shows up in ``preempted`` only — never double-counted
    in ``queued``/``in_flight`` — and the drain report counts the request once
    no matter how many times it was evicted."""
    # 5 blocks of 8 tokens for two lanes that each want 3: guaranteed pressure.
    eng, _ = _engine(max_batch=2, cache_len=32, block_size=8, n_blocks=5)
    a = Request(uid=0, prompt=np.arange(8, dtype=np.int32), max_new_tokens=16,
                priority=1)
    b = Request(uid=1, prompt=np.arange(8, dtype=np.int32), max_new_tokens=16,
                priority=0)
    eng.submit_request(a)
    eng.submit_request(b)
    saw_preempted = False
    for _ in range(200):
        if not eng.step():
            break
        st = eng.status()
        # partition invariant: every outstanding request counted exactly once
        assert st.completed + st.in_flight + st.queued + st.preempted == 2
        if b.state == "preempted":
            saw_preempted = True
            assert st.preempted >= 1 and st.queued == 0  # not double-counted
        if a.done and b.done:
            break
    assert saw_preempted, "pool pressure never evicted the low-priority request"
    status = eng.drain()
    assert a.done and b.done and status.completed == 2
    # the higher-priority request kept its lane; the victim re-admitted and
    # finished, counted ONCE in the terminal report however often it was hit
    assert a.preemptions == 0 and b.preemptions >= 1
    assert status.preempted == 1


# ---------------------------------------------------------------------------
# run() shim vs submit/drain
# ---------------------------------------------------------------------------
def test_run_shim_is_byte_identical_to_submit_drain():
    import pytest

    prompts = [np.array([1, 2, 3], dtype=np.int32),
               np.array([5, 6], dtype=np.int32),
               np.array([9], dtype=np.int32)]

    def make_requests():
        return [Request(uid=i, prompt=p.copy(), max_new_tokens=5)
                for i, p in enumerate(prompts)]

    legacy_eng, _ = _engine(max_batch=2)
    legacy = make_requests()
    with pytest.warns(DeprecationWarning, match="submit"):
        legacy_status = legacy_eng.run(legacy)

    new_eng, _ = _engine(max_batch=2)
    new = make_requests()
    for r in new:
        new_eng.submit_request(r)
    new_status = new_eng.drain()

    assert [r.output for r in new] == [r.output for r in legacy]
    assert [r.state for r in new] == [r.state for r in legacy]
    assert new_status == legacy_status  # same steps, counts, health
    np.testing.assert_array_equal(
        np.asarray(new_eng.cache["k"]), np.asarray(legacy_eng.cache["k"])
    )


# ---------------------------------------------------------------------------
# streaming regime: chunked prefill + prefix sharing
# ---------------------------------------------------------------------------
class ChunkToyModel(ToyModel):
    """Echo+1 toy that also speaks the chunked-prefill protocol."""

    def supports_chunked_prefill(self):
        return True

    def prefill_chunk(self, params, cache, tokens, start, last_row=None):
        cache = dict(cache)
        pos = start + jnp.arange(tokens.shape[1])
        cache["k"] = cache["k"].at[:, pos].set(
            tokens.astype(jnp.float32), mode="drop"
        )
        if last_row is None:
            last = tokens[:, -1:]
        else:
            last = jax.lax.dynamic_slice_in_dim(
                tokens, jnp.asarray(last_row, jnp.int32), 1, axis=1
            )
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab)
        return logits, cache


def _chunk_engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("cache_len", 32)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_buckets", (8, 16))
    return ServingEngine(ChunkToyModel(), params={}, **kw)


def test_chunked_prefill_output_invariant_to_chunk_size():
    """Echo+1 semantics must hold whatever the chunk granularity."""
    prompt = list(range(1, 11))
    want = [(prompt[-1] + 1 + i) % 17 for i in range(4)]
    for chunk in (8, 16, None):
        eng = _chunk_engine(prefill_chunk_tokens=chunk)
        assert eng._streaming
        t = eng.submit(prompt, max_new_tokens=4)
        status = eng.drain()
        assert status.completed == 1 and t.request.output == want
        # cache records the whole sequence, left-aligned (no pad offset)
        k = np.asarray(eng.pool.gather([0])["k"])[0]
        np.testing.assert_array_equal(k[: len(prompt)], prompt)


def test_prefill_budget_spreads_chunks_over_steps():
    eng = _chunk_engine(prefill_chunk_tokens=8, cache_len=64, max_batch=1)
    widths = []
    eng.on_prefill = widths.append
    t = eng.submit(list(range(1, 34)), max_new_tokens=2)  # 32-token body
    eng.step()
    assert widths == [8]  # one budgeted chunk per step, not the whole body
    assert t.request.state == "prefilling"
    eng.drain()
    assert t.done and sum(widths) == 32


def test_prefix_sharing_aliases_blocks_and_counts_hits():
    eng = _chunk_engine(prefill_chunk_tokens=16, cache_len=64)
    sys_prompt = list(range(1, 17))  # two full 8-token blocks once admitted
    t1 = eng.submit(sys_prompt + [3], max_new_tokens=2)
    eng.drain()
    assert eng.status().prefix_lookups == 1 and eng.status().prefix_hits == 0
    t2 = eng.submit(sys_prompt + [5], max_new_tokens=2)
    eng.drain()
    st = eng.status()
    assert st.prefix_hits == 1 and st.prefix_lookups == 2
    assert st.prefix_hit_rate == 0.5
    assert eng._prefix_reused_tokens == 16  # both shared blocks skipped prefill
    assert t1.request.output == [4, 5] and t2.request.output == [6, 7]


def test_prefix_sharing_disabled_is_inert():
    eng = _chunk_engine(prefill_chunk_tokens=16, cache_len=64,
                        prefix_sharing=False)
    sys_prompt = list(range(1, 17))
    for tail in ([3], [5]):
        eng.submit(sys_prompt + tail, max_new_tokens=2)
    eng.drain()
    st = eng.status()
    assert st.prefix_lookups == 0 and st.shared_blocks == 0
    assert eng.prefix_overlap(sys_prompt + [9]) == 0


def test_engine_status_reports_pool_health():
    eng = _chunk_engine(prefill_chunk_tokens=16, cache_len=64)
    sys_prompt = list(range(1, 17))
    eng.submit(sys_prompt + [3], max_new_tokens=8)
    eng.step()  # first request activates and registers its prefix
    eng.submit(sys_prompt + [5], max_new_tokens=8)
    eng.step()  # sibling aliases the live lane's blocks: refcount > 1
    st = eng.status()
    assert 0.0 < st.pool_utilization <= 1.0
    assert 0.0 <= st.pool_fragmentation < 1.0
    assert st.shared_blocks == 2 and st.prefix_hits == 1


def test_pick_victim_protects_shared_prefix_holders():
    eng = _chunk_engine(max_batch=3, cache_len=64, prefill_chunk_tokens=16,
                        n_blocks=12)
    sys_prompt = list(range(1, 18))  # body = 16 tokens = 2 shareable blocks
    a = eng.submit(sys_prompt + [3], max_new_tokens=12).request
    eng.step()  # a activates and registers its prefix
    b = eng.submit(sys_prompt + [5], max_new_tokens=12).request
    c = eng.submit(list(range(60, 70)), max_new_tokens=12).request
    while b.state != "active" or c.state != "active":
        assert eng.step()
    lanes = {r.uid: lane for lane, r in enumerate(eng.slots) if r is not None}
    assert eng.pool.lane_holds_shared(lanes[a.uid])
    assert eng.pool.lane_holds_shared(lanes[b.uid])
    assert not eng.pool.lane_holds_shared(lanes[c.uid])
    # a has emitted most (admitted earliest) so unprotected ranking would
    # pick it; protection must steer eviction to the private lane instead
    running = [r for r in eng.slots if r is not None]
    assert eng._pick_victim(running) is c
    # with only shared holders running, the fallback still yields a victim
    assert eng._pick_victim([b]) is b


def test_preempted_shared_prefix_request_readmits():
    """Preemption of a lane whose prefix blocks are aliased by a live sibling
    must re-admit cleanly (refcounts make the release safe), and the evicted
    request's output must stay a seamless continuation."""
    eng = _chunk_engine(max_batch=2, cache_len=64, prefill_chunk_tokens=16,
                        n_blocks=8)
    sys_prompt = list(range(1, 18))  # 2 shared blocks once registered
    a = eng.submit(sys_prompt + [3], max_new_tokens=30, priority=0).request
    eng.step()  # a activates and registers its prefix
    b = eng.submit(sys_prompt + [5], max_new_tokens=30, priority=0).request
    while b.state != "active":
        assert eng.step()
    assert eng.pool.shared_blocks == 2  # b rides on a's blocks
    # Both decodes grow past what 8 blocks can hold.  Every lane holds shared
    # blocks, so the protected pick falls back and evicts one holder anyway —
    # release just drops the refcount, the sibling's alias stays intact.
    status = eng.drain()
    assert a.done and b.done and not status.exhausted
    assert status.preempted >= 1 and a.preemptions + b.preemptions >= 1
    # echo+1 ramps survive eviction + re-admission unbroken
    assert a.output == [(4 + i) % 17 for i in range(30)]
    assert b.output == [(6 + i) % 17 for i in range(30)]


# ---------------------------------------------------------------------------
# geometric bucket ladder (prompts longer than the largest configured bucket)
# ---------------------------------------------------------------------------
def test_extend_ladder_doubles_to_cache_len():
    from repro.serve.engine import _extend_ladder

    assert _extend_ladder((8, 16), 256) == (8, 16, 32, 64, 128)
    assert _extend_ladder((8, 16), 32) == (8, 16)  # seed geometry: unchanged
    assert _extend_ladder((8,), 64) == (8, 16, 32)


def test_long_prompts_share_one_extended_bucket():
    """Prompts past the configured ladder must not truncate, and near-length
    prompts must share one extended bucket (one retrace, not one per length)."""
    eng, _ = _engine(max_batch=2, cache_len=256)
    t1 = eng.submit(list(range(1, 101)), max_new_tokens=2)
    t2 = eng.submit(list(range(1, 121)), max_new_tokens=2)
    eng.drain()
    assert t1.request.truncated_tokens == 0
    assert t2.request.truncated_tokens == 0
    assert t1.done and t2.done
    assert list(eng._prefill_cache) == [128]  # both hit the same 128 bucket
