"""Trainer integration: resume-exactness, preemption, microbatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _mk(tmp_path, total=8, ckpt_every=4, micro=1, lr=1e-3, batch=4, seq=16):
    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    data = DataConfig(global_batch=batch, seq_len=seq)
    t = TrainerConfig(
        total_steps=total, ckpt_every=ckpt_every, ckpt_dir=str(tmp_path), log_every=100,
        num_microbatches=micro,
    )
    opt = adamw.AdamWConfig(lr=lr, total_steps=total, warmup_steps=5)
    return Trainer(model, cfg, data, opt, t)


def _leaves(params):
    return np.concatenate([np.asarray(x).ravel() for x in jax.tree.leaves(params)])


def test_resume_exactness(tmp_path):
    """Interrupted-then-resumed == uninterrupted (deterministic pipeline)."""
    t1 = _mk(tmp_path / "a", total=6, ckpt_every=3)
    step, p_full, _, _ = t1.train()
    assert step == 6

    t2 = _mk(tmp_path / "b", total=6, ckpt_every=3)
    t2.train(stop_after=3)  # stops at step 3, checkpointed
    t3 = _mk(tmp_path / "b", total=6, ckpt_every=3)
    assert t3.ckpt.latest_step() == 3
    step, p_resumed, _, _ = t3.train()  # resumes 3 -> 6
    assert step == 6
    np.testing.assert_allclose(_leaves(p_full), _leaves(p_resumed), rtol=1e-5, atol=1e-6)


def test_preemption_checkpoints(tmp_path):
    t = _mk(tmp_path, total=50, ckpt_every=100)
    # trigger preemption after the first step via the straggler hook window
    from repro.core.faults import PreemptionGuard

    orig_enter = PreemptionGuard.__enter__

    def patched(self):
        out = orig_enter(self)
        self.request()
        return out

    PreemptionGuard.__enter__ = patched
    try:
        step, *_ = t.train()
    finally:
        PreemptionGuard.__enter__ = orig_enter
    assert step == 1
    assert t.ckpt.latest_step() == 1  # emergency checkpoint committed


def test_microbatch_equivalence():
    """grad accumulation over k microbatches == single full batch (f32)."""
    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    s1 = make_train_step(model, opt_cfg, num_microbatches=1)
    s2 = make_train_step(model, opt_cfg, num_microbatches=2)
    p1, _, m1 = s1(params, adamw.init(params), batch)
    p2, _, m2 = s2(params, adamw.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(_leaves(p1), _leaves(p2), rtol=1e-4, atol=1e-5)


def test_loss_decreases(tmp_path):
    """End-to-end learnability: markov data + 40 steps => loss drops."""
    t = _mk(tmp_path, total=45, ckpt_every=1000, lr=3e-3, batch=16, seq=64)
    t.tcfg.log_every = 5
    t.train()
    first = t.history[0]["loss"]
    last = t.history[-1]["loss"]
    assert last < first - 0.3, (first, last)
