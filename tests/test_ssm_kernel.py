"""Pallas selective-SSM kernel vs the associative-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import ssm_scan_ref
from repro.kernels.ssm import DEFAULT_SSM_CONFIG, SsmConfig, ssm_config_space, ssm_scan_pallas
from repro.core.runtime import default_runtime as rt


def _inputs(bsz, s, d, n, seed=0, with_state=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    dtx = jax.random.normal(ks[0], (bsz, s, d)) * 0.5
    dta = -jnp.exp(jax.random.normal(ks[1], (bsz, s, d, n)) * 0.3)
    b = jax.random.normal(ks[2], (bsz, s, n)) * 0.5
    c = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
    state = jax.random.normal(ks[4], (bsz, d, n)) * 0.1 if with_state else None
    return dtx, dta, b, c, state


def _run_pallas(dtx, dta, b, c, state, cfg):
    bsz, _, d = dtx.shape
    st = state if state is not None else jnp.zeros((bsz, d, b.shape[-1]), jnp.float32)
    one = lambda x_, a_, b_, c_, s_: ssm_scan_pallas(x_, a_, b_, c_, s_, cfg, interpret=True)
    return jax.vmap(one)(dtx, dta, b, c, st)


@pytest.mark.parametrize("s,d", [(7, 32), (50, 100), (64, 128)])
@pytest.mark.parametrize("with_state", [True, False])
def test_ssm_shapes(s, d, with_state):
    args = _inputs(2, s, d, 16, with_state=with_state)
    y_ref, s_ref = ssm_scan_ref(*args)
    y_p, s_p = _run_pallas(*args, DEFAULT_SSM_CONFIG)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("cfg", ssm_config_space()[::2])
def test_ssm_config_sweep(cfg):
    args = _inputs(1, 70, 96, 8, seed=2)
    y_ref, s_ref = ssm_scan_ref(*args)
    y_p, s_p = _run_pallas(*args, cfg)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-5)


def test_ops_ssm_paths_agree():
    args = _inputs(2, 33, 48, 16, seed=4)
    y_ref, s_ref = ops.ssm_scan(*args)
    rt().set_pallas_enabled(True, interpret=True)
    try:
        y_p, s_p = ops.ssm_scan(*args)
    finally:
        rt().set_pallas_enabled(False)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_ref), rtol=1e-4, atol=1e-5)


def test_hymba_model_both_paths():
    """Hymba loss identical on jnp and Pallas-interpret dispatch paths."""
    from repro.configs import registry
    from repro.models.model import build_model

    cfg = registry.get("hymba-1.5b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    loss_ref, _ = model.loss_fn(params, batch)
    assert np.isfinite(float(loss_ref))
    rt().set_pallas_enabled(True, interpret=True)
    try:
        loss_p, _ = model.loss_fn(params, batch)
    finally:
        rt().set_pallas_enabled(False)
    np.testing.assert_allclose(float(loss_p), float(loss_ref), rtol=1e-4)


def test_mamba_prefill_decode_consistency_still_holds():
    """The fused scan keeps the hymba prefill->decode invariant intact."""
    from repro.configs import registry
    from repro.models.mamba import init_mamba, mamba_decode_step, mamba_layer

    cfg = registry.get("hymba-1.5b").reduced()
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    p1 = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model)) * 0.5
    y_full, h_full = mamba_layer(p1, x, cfg)
    y_pre, h_pre = mamba_layer(p1, x[:, :8], cfg)
    y_dec, h_dec = mamba_decode_step(p1, x[:, 8:9], h_pre, cfg)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 8]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_full), rtol=2e-3, atol=2e-3)
