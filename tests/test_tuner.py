"""Integration tests: perf model -> tuning pipeline -> deployment -> dispatch."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codegen import dict_to_tree, tree_to_dict, tree_to_python
from repro.core.dataset import (
    TuningDataset,
    build_model_dataset,
    harvest_problems,
    problem_features,
    synthetic_problems,
)
from repro.core.dispatch import Deployment, build_labels, classifier_fraction, train_deployment
from repro.core.normalize import normalize
from repro.core.perfmodel import TPU_V4, TPU_V5E, predict_gflops, predict_time
from repro.core.selection import achievable_fraction, select_from_dataset
from repro.core.tuner import tune, tune_for_archs
from repro.kernels import ops
from repro.kernels.matmul import MatmulConfig, config_space
from repro.core.runtime import default_runtime as rt


# ---------------------------------------------------------------------------
# perf model
# ---------------------------------------------------------------------------
def test_perfmodel_basics():
    p = (512, 784, 512, 16)
    g = [predict_gflops(p, c) for c in config_space()]
    g = np.array(g)
    assert np.all(g >= 0) and g.max() > 1000  # multi-teraflop territory
    # VMEM-overflow config fails (0 gflops), like a kernel the driver rejects
    bad = MatmulConfig(512, 512, 16384, "mnk")
    assert bad.vmem_bytes() > TPU_V5E.vmem_bytes
    assert predict_gflops(p, bad) == 0.0
    assert predict_time(p, bad) == float("inf")


def test_perfmodel_regimes():
    """The paper's §3.2 shape regimes reproduce on the analytic model."""
    space = config_space()
    # Tall-skinny problems perform poorly in ALL configurations (paper Fig. 1):
    skinny = (1, 12288, 512, 1)
    square = (4096, 4096, 4096, 1)
    best_skinny = max(predict_gflops(skinny, c) for c in space)
    best_square = max(predict_gflops(square, c) for c in space)
    assert best_skinny < 0.05 * best_square
    # Large square problems prefer MXU-filling blocks:
    best_cfg = space[int(np.argmax([predict_gflops(square, c) for c in space]))]
    assert best_cfg.block_m >= 128 and best_cfg.block_n >= 128
    # devices differ (the paper's AMD vs Intel analogue)
    g5 = predict_gflops(square, best_cfg, TPU_V5E)
    g4 = predict_gflops(square, best_cfg, TPU_V4)
    assert g4 != g5


def test_perfmodel_long_tail():
    """Many configs are optimal somewhere (paper Fig. 2's long tail)."""
    ds = build_model_dataset(synthetic_problems(150))
    winners = set(ds.perf.argmax(1).tolist())
    assert len(winners) >= 10


# ---------------------------------------------------------------------------
# dataset
# ---------------------------------------------------------------------------
def test_dataset_split_and_roundtrip(tmp_path):
    ds = build_model_dataset(synthetic_problems(40))
    tr, te = ds.split(0.25, seed=1)
    assert len(tr.problems) + len(te.problems) == len(ds.problems)
    assert not (set(tr.problems) & set(te.problems))
    path = tmp_path / "ds.npz"
    ds.save(path)
    back = TuningDataset.load(path)
    assert back.problems == ds.problems
    np.testing.assert_allclose(back.perf, ds.perf)
    assert back.configs == ds.configs


def test_harvest_problems_covers_archs():
    probs = harvest_problems(["phi4-mini-3.8b", "qwen3-moe-235b-a22b"])
    assert len(probs) > 20
    assert all(len(p) == 4 and all(v >= 1 for v in p) for p in probs)
    feats = problem_features(probs)
    assert feats.shape == (len(probs), 6)
    assert np.all(np.isfinite(feats))


# ---------------------------------------------------------------------------
# selection + deployment
# ---------------------------------------------------------------------------
def test_selection_beats_few_random(rng):
    ds = build_model_dataset(synthetic_problems(120))
    tr, te = ds.split()
    chosen = select_from_dataset(tr, 8, "pca_kmeans", "standard")
    frac = achievable_fraction(te.perf, chosen)
    rand_frac = np.mean(
        [
            achievable_fraction(te.perf, list(rng.choice(len(ds.configs), 8, replace=False)))
            for _ in range(5)
        ]
    )
    assert frac > 0.85
    assert frac > rand_frac


def test_tune_end_to_end():
    ds = build_model_dataset(synthetic_problems(100))
    res = tune(ds, n_kernels=8, method="pca_kmeans", classifier="DecisionTreeA")
    assert 0.7 < res.classifier_fraction <= res.oracle_fraction <= 1.0
    assert len(res.deployment.configs) == 8
    # the deployed policy picks only deployed configs
    cfg = res.deployment.select_matmul(512, 784, 512, 16)
    assert cfg in res.deployment.configs


def test_tune_for_archs_small():
    res = tune_for_archs(["granite-8b"], n_kernels=6, max_problems=40)
    assert res.oracle_fraction > 0.8


def test_deployment_roundtrip(tmp_path):
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    path = tmp_path / "deploy.json"
    res.deployment.save(path)
    back = Deployment.load(path)
    assert back.configs == res.deployment.configs
    for p in [(64, 256, 512, 1), (1, 4096, 1024, 1), (2048, 2048, 2048, 8)]:
        assert back.select_matmul(*p) == res.deployment.select_matmul(*p)
    assert json.loads(path.read_text())["classifier_name"] == "DecisionTreeA"


def test_codegen_matches_tree():
    ds = build_model_dataset(synthetic_problems(60))
    tr, _ = ds.split()
    chosen = select_from_dataset(tr, 5, "kmeans", "standard")
    dep = train_deployment(tr, chosen, "DecisionTreeB")
    src = tree_to_python(dep.classifier)
    ns = {}
    exec(src, ns)  # noqa: S102 — generated launcher code, the paper's embedding
    feats = tr.features
    want = dep.classifier.predict(feats)
    got = [ns["select_kernel"](*row) for row in feats]
    assert list(want) == got
    # dict round-trip preserves predictions too
    back = dict_to_tree(tree_to_dict(dep.classifier))
    assert list(back.predict(feats)) == list(want)


def test_classifier_fraction_bounds():
    ds = build_model_dataset(synthetic_problems(80))
    tr, te = ds.split()
    chosen = select_from_dataset(tr, 6, "kmeans", "standard")
    dep = train_deployment(tr, chosen, "DecisionTreeA")
    frac = classifier_fraction(te, chosen, dep)
    oracle = achievable_fraction(te.perf, chosen)
    assert 0 < frac <= oracle + 1e-9
    labels = build_labels(tr.perf, chosen)
    assert labels.max() < len(chosen)


# ---------------------------------------------------------------------------
# dispatch hook in ops
# ---------------------------------------------------------------------------
def test_ops_matmul_uses_policy():
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    rt().install(res.deployment)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    try:
        a = jnp.ones((4, 64, 128))
        b = jnp.ones((128, 256))
        out = ops.matmul(a, b)
        assert out.shape == (4, 64, 256)
        log = ops.selection_log()
        assert log and log[0][0] == "matmul"
        # 3-D lhs featurizes with its real leading batch — the tuning
        # dataset's (m, k, n, batch) convention, not a flattened (256, ..., 1).
        assert log[0][1] == (64, 128, 256, 4)
        assert isinstance(log[0][2], MatmulConfig)
        assert log[0][2] in res.deployment.configs
        # the second identical-shape dispatch is a shape-cache hit
        stats0 = ops.shape_cache_stats()
        ops.matmul(a, b)
        stats1 = ops.shape_cache_stats()
        assert stats1["hits"] == stats0["hits"] + 1
        assert stats1["misses"] == stats0["misses"]
    finally:
        rt().install(None)
        rt().set_selection_logging(False)
        rt().clear_selection_log()


def test_ops_matmul_batch_featurization():
    """2-D -> batch 1; 3-D -> leading batch; 4-D -> product of lead dims."""
    ds = build_model_dataset(synthetic_problems(60))
    res = tune(ds, n_kernels=5)
    rt().install(res.deployment)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    try:
        b = jnp.ones((32, 64))
        ops.matmul(jnp.ones((16, 32)), b)
        ops.matmul(jnp.ones((8, 16, 32)), b)
        ops.matmul(jnp.ones((2, 3, 16, 32)), b)
        problems = [p for op, p, _ in ops.selection_log() if op == "matmul"]
        assert problems == [(16, 32, 64, 1), (16, 32, 64, 8), (16, 32, 64, 6)]
    finally:
        rt().install(None)
        rt().set_selection_logging(False)
        rt().clear_selection_log()


def test_ops_matmul_pallas_path_matches_xla():
    a = jnp.linspace(-1, 1, 64 * 96, dtype=jnp.float32).reshape(64, 96)
    b = jnp.linspace(1, -1, 96 * 128, dtype=jnp.float32).reshape(96, 128)
    want = ops.matmul(a, b)
    rt().set_pallas_enabled(True, interpret=True)
    try:
        got = ops.matmul(a, b)
    finally:
        rt().set_pallas_enabled(False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_normalize_then_select_is_stable():
    """Same seed + data => identical selection (fully deterministic pipeline)."""
    ds = build_model_dataset(synthetic_problems(60))
    a = select_from_dataset(ds, 6, "pca_kmeans", "sigmoid", seed=3)
    b = select_from_dataset(ds, 6, "pca_kmeans", "sigmoid", seed=3)
    assert a == b
    n = normalize(ds.perf, "sigmoid")
    assert n.shape == ds.perf.shape
