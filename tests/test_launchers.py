"""CLI launcher smoke tests: tune / train / serve mains end to end."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import Deployment
from repro.core.runtime import reset_default_runtime
from repro.kernels import ops


@pytest.fixture(autouse=True)
def _fresh_runtime():
    # Real test isolation: each test gets a brand-new default runtime instead
    # of the old clear_*-everything teardown choreography.
    rt = reset_default_runtime()
    rt.set_selection_logging(True)
    yield rt
    reset_default_runtime()


def test_tune_cli_v5e(tmp_path):
    from repro.launch.tune import main

    out = tmp_path / "deploy.json"
    main(["--device", "tpu_v5e", "--archs", "granite-8b", "--n-kernels", "6",
          "--max-problems", "60", "--out", str(out)])
    dep = Deployment.load(out)
    assert len(dep.configs) == 6
    assert dep.attention_tree is not None
    assert dep.meta["oracle_fraction"] > 0.8


def test_tune_cli_measured_cpu(tmp_path):
    from repro.launch.tune import main

    out = tmp_path / "deploy_cpu.json"
    main(["--device", "host_cpu", "--cpu-problems", "6", "--n-kernels", "4",
          "--out", str(out)])
    dep = Deployment.load(out)
    assert dep.device == "host_cpu"
    assert len(dep.configs) == 4


def test_train_cli_with_deployment(tmp_path):
    from repro.launch.train import main as train_main
    from repro.launch.tune import main as tune_main

    dep = tmp_path / "d.json"
    tune_main(["--device", "tpu_v5e", "--archs", "granite-8b", "--max-problems", "40",
               "--out", str(dep)])
    train_main([
        "--arch", "granite-8b", "--reduced", "--steps", "4", "--batch", "2",
        "--seq", "16", "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "2",
        "--deployment", str(dep),
    ])
    # the deployment was installed and consulted at trace time
    assert any(op == "matmul" for op, _, _ in ops.selection_log())


def test_serve_cli(tmp_path, capsys):
    from repro.launch.serve import main as serve_main

    serve_main(["--arch", "granite-8b", "--requests", "3", "--max-new-tokens", "4",
                "--max-batch", "2", "--cache-len", "64"])
    out = capsys.readouterr().out
    assert "served 3 requests" in out


def test_tune_cli_bundle_then_serve_cli(tmp_path, capsys, monkeypatch):
    """Fleet-tune a two-device bundle, then serve from it on a chosen device."""
    from repro.core.bundle import DeploymentBundle
    from repro.launch.serve import main as serve_main
    from repro.launch.tune import main as tune_main

    out = tmp_path / "bundle.json"
    tune_main(["--devices", "tpu_v5e,tpu_v4", "--archs", "granite-8b",
               "--n-kernels", "4", "--max-problems", "40", "--bundle", str(out)])
    bundle = DeploymentBundle.load(out)
    assert bundle.devices == ["tpu_v4", "tpu_v5e"]
    capsys.readouterr()

    serve_main(["--arch", "granite-8b", "--requests", "2", "--max-new-tokens", "4",
                "--max-batch", "2", "--cache-len", "64",
                "--bundle", str(out), "--serve-device", "tpu_v4"])
    printed = capsys.readouterr().out
    assert "serving with the 'tpu_v4' deployment" in printed
    assert "served 2 requests" in printed
    # the serving traces consulted the bundle's tuned policy (the CLI's
    # private runtime reports nonzero selection counters only when a live
    # policy answered trace-time dispatch)
    m = re.search(r"policy selections at trace time: (\d+)", printed)
    assert m and int(m.group(1)) > 0, printed
    # the launcher owns an isolated KernelRuntime: serving from the bundle
    # must leave the process default runtime untouched (multi-tenant contract)
    assert ops.active_device() is None


def test_serve_engine_with_kv_quant():
    """Serving engine composes with the int8 KV cache."""
    from repro.configs import registry
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServingEngine

    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32, kv_quant=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, cache_len=64)
    assert eng.cache["k"].dtype == jnp.int8
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=5).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    eng.run(reqs)
    assert all(r.done and len(r.output) == 4 for r in reqs)


def test_tune_cli_measure_budget_type():
    import argparse

    from repro.launch.tune import _measure_budget

    assert _measure_budget("auto") == "auto"
    assert _measure_budget("0.4") == 0.4
    for bad in ("0", "1", "1.5", "-0.2", "most", ""):
        with pytest.raises(argparse.ArgumentTypeError):
            _measure_budget(bad)


def test_ctl_cli_submit_status_artifacts(capsys):
    from repro.control import ControlPlane
    from repro.core.bundle import DeploymentBundle
    from repro.core.dataset import build_model_dataset, synthetic_problems
    from repro.core.tuner import tune
    from repro.launch.ctl import main

    ds = build_model_dataset(synthetic_problems(40), device_name="tpu_v5e")
    bundle = DeploymentBundle({"tpu_v5e": tune(ds, n_kernels=4).deployment})
    with ControlPlane(port=0, tuner=lambda spec: bundle) as plane:
        main(["submit", "--url", plane.url, "--name", "fleet",
              "--devices", "tpu_v5e", "--measure-budget", "auto", "--wait"])
        out = capsys.readouterr().out
        assert "job-0001 queued" in out and "job-0001 succeeded" in out
        ver = plane.registry.latest("fleet").version
        assert f"artifact fleet@{ver}" in out
        assert f"registry://127.0.0.1:{plane.port}/fleet/{ver}" in out

        main(["status", "--url", plane.url])
        out = capsys.readouterr().out
        assert '"status": "ok"' in out
        assert "job-0001 [tune] succeeded -> fleet@" in out

        main(["artifacts", "--url", plane.url])
        assert f"fleet@{ver} seq=0" in capsys.readouterr().out


def test_ctl_cli_submit_failed_job_exits_nonzero(capsys):
    from repro.control import ControlPlane
    from repro.launch.ctl import main

    def tuner(spec):
        raise ValueError("no benchmarks on this host")

    with ControlPlane(port=0, tuner=tuner) as plane:
        with pytest.raises(SystemExit):
            main(["submit", "--url", plane.url, "--wait", "--timeout", "30"])
        out = capsys.readouterr().out
        assert "failed: ValueError: no benchmarks on this host" in out
