"""Staged tuning pipeline: stages, pruning, transfer warm-start, budgets,
lineage provenance, fleet determinism, and the FALLBACKS transfer graph."""
import json

import numpy as np
import pytest

from repro.core import devices as dev
from repro.core import pipeline as pl
from repro.core.bundle import DeploymentBundle
from repro.core.dataset import harvest_problems
from repro.core.devices import (
    fallback_order,
    resolve_device,
    transfer_donor,
    transfer_order,
)
from repro.core.families import get_family
from repro.core.normalize import normalize
from repro.core.tuner import tune_family, tune_fleet, tune_for_archs

ARCHS = ["phi4-mini-3.8b"]


# ---------------------------------------------------------------------------
# stage results
# ---------------------------------------------------------------------------
def test_stages_compose_and_account():
    cand = pl.generate_candidates("wkv")
    assert cand.family == "wkv" and cand.problems and cand.configs
    prune = pl.prune_candidates(cand, prune_ratio=0.5)
    assert 0 < len(prune.kept) < len(cand.configs) or len(cand.configs) <= 2
    assert list(prune.kept) == sorted(prune.kept)  # stable column order
    plan = pl.plan_measurements(cand, prune, measure_budget=0.4)
    meas = pl.run_measurements(cand, prune, plan)
    assert meas.perf.shape == (len(cand.problems), len(prune.kept))
    assert meas.full_cost == len(cand.problems) * len(cand.configs)
    assert meas.n_measured == int(plan.mask.sum())
    assert meas.measured_fraction <= 0.4 + 1e-9
    assert np.all(meas.perf > 0)  # model-filled cells are real predictions


def test_prune_always_keeps_default_and_donor_configs():
    fam = get_family("ssm_scan")
    cand = pl.generate_candidates("ssm_scan")
    donor_cfg = cand.configs[-1]
    prune = pl.prune_candidates(cand, prune_ratio=0.2, keep_configs=[donor_cfg])
    kept_cfgs = [cand.configs[j] for j in prune.kept]
    assert fam.default_config in kept_cfgs
    assert donor_cfg in kept_cfgs


def test_budget_ignored_without_model_table():
    # No predicted table -> nothing can fill unmeasured cells, so the plan
    # measures everything and the budget is (safely) inapplicable.
    cand = pl.generate_candidates("wkv")
    prune = pl.PruneStage(kept=tuple(range(len(cand.configs))), predicted=None, ratio=1.0)
    plan = pl.plan_measurements(cand, prune, measure_budget=0.1)
    assert plan.mask.all()


def test_full_default_pipeline_is_bit_identical_to_legacy_monolith():
    """No prune / no budget / no donor must reproduce the old tune_family."""
    from repro.core.cluster import select_configs
    from repro.core.selection import achievable_fraction, geomean_fraction

    fam = get_family("ssm_scan")
    space = list(fam.config_space())
    problems = fam.harvest(None)
    perf = fam.perf_matrix(problems, space, None)
    norm = normalize(perf, "standard")
    feats = fam.features(problems)
    k = min(fam.default_n_kernels, len(space))
    chosen = select_configs(norm, k, "pca_kmeans", features=feats, seed=0)
    labels = perf[:, chosen].argmax(axis=1)
    tree = fam.make_tree().fit(feats, labels)

    res = pl.run_family_pipeline("ssm_scan")
    assert res.chosen == [int(i) for i in chosen]
    assert res.configs == [space[i] for i in chosen]
    assert np.array_equal(res.tree.predict(feats), tree.predict(feats))
    assert res.oracle_fraction == achievable_fraction(perf, chosen)
    pred = np.clip(tree.predict(feats), 0, len(chosen) - 1)
    picked = perf[np.arange(len(problems)), [chosen[i] for i in pred]]
    assert res.classifier_fraction == geomean_fraction(picked, perf.max(axis=1))
    assert res.lineage["measured_fraction"] == 1.0
    assert res.lineage["source_device"] is None


# ---------------------------------------------------------------------------
# transfer warm-start
# ---------------------------------------------------------------------------
def test_transfer_measures_only_disagreements():
    full = tune_family("wkv")
    staged = tune_family("wkv", transfer_from=full, measure_budget=0.5)
    assert staged.lineage["measured_fraction"] <= 0.5 + 1e-9
    assert staged.lineage["measured_fraction"] < 1.0
    # warm-started selection stays close to the full tune's quality
    assert staged.classifier_fraction >= 0.9 * full.classifier_fraction


def test_as_transfer_prior_accepts_all_artifact_shapes():
    full = tune_family("wkv")
    for obj in (
        full,  # FamilyTuneResult
        (full.configs, full.tree),  # bare tuple
        pl.TransferPrior(full.configs, full.tree, "tpu_v4"),  # already normalized
    ):
        prior = pl.as_transfer_prior(obj, "wkv")
        assert prior is not None and prior.configs == full.configs
    assert pl.as_transfer_prior(None, "wkv") is None
    assert pl.as_transfer_prior(pl.TransferPrior([], None), "wkv") is None


def test_transfer_prior_from_deployment_records_source_device():
    donor = tune_for_archs(ARCHS, device_name="tpu_v5e", max_problems=30, families=[])
    prior = pl.as_transfer_prior(donor, "matmul")
    assert prior.source_device == "tpu_v5e"
    assert prior.configs == donor.deployment.configs


def test_tune_for_archs_transfer_stamps_lineage_and_saves_measurements():
    donor = tune_for_archs(ARCHS, device_name="tpu_v5e", max_problems=30, families=[])
    target = tune_for_archs(
        ARCHS, device_name="tpu_v4", max_problems=30, families=[],
        transfer_from=donor, prune_ratio=0.5, measure_budget=0.4,
    )
    lin = target.deployment.meta["tuning_lineage"]["matmul"]
    assert lin["source_device"] == "tpu_v5e"
    assert lin["prune_ratio"] <= 0.75  # donor + default configs can push past 0.5
    assert lin["measured_fraction"] <= 0.4 + 1e-9
    assert lin["n_measured"] < lin["full_cost"]
    assert lin["model_error"] is not None and lin["model_error"] < 0.5
    # still a useful artifact
    assert target.classifier_fraction > 0.7


def test_untouched_tune_has_identity_lineage():
    res = tune_for_archs(ARCHS, device_name="tpu_v5e", max_problems=30, families=[])
    lin = res.deployment.meta["tuning_lineage"]["matmul"]
    assert lin["measured_fraction"] == 1.0 and lin["prune_ratio"] == 1.0
    assert lin["source_device"] is None


def test_warm_start_centers_shared_with_retune():
    from repro.core.retune import _warm_start_centers

    rng = np.random.default_rng(0)
    perf = rng.uniform(1, 2, size=(12, 5))
    norm = normalize(perf, "standard")
    configs = list("abcde")
    centers = pl.warm_start_centers(norm, configs, perf, ["b", "d"])
    assert centers is not None and centers.shape[1] == 5 and len(centers) <= 2
    assert np.array_equal(
        centers, _warm_start_centers(norm, configs, perf, ["b", "d"])
    )
    assert pl.warm_start_centers(norm, configs, perf, ["zz"]) is None


# ---------------------------------------------------------------------------
# lineage provenance through bundles
# ---------------------------------------------------------------------------
def test_fleet_transfer_lineage_survives_bundle_roundtrip(tmp_path):
    fleet = tune_fleet(
        ARCHS, device_names=("tpu_v5e", "tpu_v4"), max_problems=30,
        families=["wkv"], transfer=True, measure_budget=0.4,
    )
    # devices tuned donor-first; the second one warm-started off the first
    lineages = {
        name: r.deployment.meta["tuning_lineage"]["matmul"]
        for name, r in fleet.results.items()
    }
    donors = [lin["source_device"] for lin in lineages.values()]
    assert donors.count(None) == 1  # exactly one bootstrap full tune
    (transferred,) = [d for d in donors if d is not None]
    assert transferred in lineages  # donor is a fleet member tuned earlier
    saved = [lin for lin in lineages.values() if lin["measured_fraction"] < 1.0]
    assert saved, "transfer tune should not re-measure the full table"

    path = tmp_path / "bundle.json"
    fleet.bundle.save(path)
    loaded = DeploymentBundle.load(path)
    for name, lin in lineages.items():
        assert loaded.deployments[name].meta["tuning_lineage"]["matmul"] == lin


# ---------------------------------------------------------------------------
# fleet determinism (seed threading regression)
# ---------------------------------------------------------------------------
def _fleet_fingerprint(seed):
    fleet = tune_fleet(
        ARCHS, device_names=("tpu_v5e", "tpu_v4"), max_problems=30,
        families=["wkv", "ssm_scan"], seed=seed,
    )
    return {
        name: json.dumps(r.deployment.to_blob(), sort_keys=True)
        for name, r in fleet.results.items()
    }


def test_fleet_tune_is_bit_reproducible_run_to_run():
    a = _fleet_fingerprint(seed=3)
    b = _fleet_fingerprint(seed=3)
    assert a == b  # same seed -> byte-identical deployments, every device/family


# ---------------------------------------------------------------------------
# the FALLBACKS transfer graph (resolve_device fallback chains)
# ---------------------------------------------------------------------------
def test_resolve_device_unknown_falls_back_to_family_default():
    # tpu_v9 has no FALLBACKS entry: family rule picks a tuned TPU
    assert resolve_device("tpu_v9", ["host_cpu", "tpu_v4"]) == "tpu_v4"
    # and only the serve-anything last resort crosses families
    assert resolve_device("tpu_v9", ["host_cpu"]) == "host_cpu"
    with pytest.raises(KeyError):
        resolve_device("tpu_v9", ["host_cpu"], strict=True)


def test_resolve_device_multi_hop_sibling_walk():
    # v2 -> v3 -> v4 -> v5p is not in any direct chain; BFS finds it
    assert "tpu_v5p" in fallback_order("tpu_v2")
    assert resolve_device("tpu_v2", ["tpu_v5p"]) == "tpu_v5p"
    # nearer hop still wins when available
    assert resolve_device("tpu_v2", ["tpu_v5p", "tpu_v3"]) == "tpu_v3"


def test_fallback_order_is_cycle_safe(monkeypatch):
    monkeypatch.setattr(
        dev, "FALLBACKS", {"a": ("b",), "b": ("c",), "c": ("a", "b")}
    )
    assert fallback_order("a") == ["b", "c"]  # terminates, no repeats
    assert fallback_order("b") == ["c", "a"]
    assert "a" not in fallback_order("a")  # never its own sibling


def test_transfer_donor_never_crosses_platform_family():
    assert transfer_donor("tpu_v4", ["tpu_v5e", "host_cpu"]) == "tpu_v5e"
    assert transfer_donor("tpu_v4", ["host_cpu"]) is None
    assert transfer_donor("tpu_v4", ["tpu_v4"]) is None  # self is not a donor
    # multi-hop: v2's graph reaches v5p through v3/v4
    assert transfer_donor("tpu_v2", ["tpu_v5p"]) == "tpu_v5p"


def test_transfer_order_places_donors_first():
    order = transfer_order(["tpu_v6e", "tpu_v4", "tpu_v5e"])
    assert sorted(order) == ["tpu_v4", "tpu_v5e", "tpu_v6e"]
    # everything after the bootstrap root has a donor among its predecessors
    for i, name in enumerate(order[1:], start=1):
        assert transfer_donor(name, order[:i]) is not None
    # deterministic + dedupes canonicalized spellings
    assert transfer_order(["TPU v4", "tpu_v4"]) == ["tpu_v4"]
    assert transfer_order(["host_cpu"]) == ["host_cpu"]


def test_measure_budget_zero_rows_still_yields_artifact():
    # an absurdly small budget degrades to a pure model+donor tune, not a crash
    full = tune_family("ssm_scan")
    staged = tune_family("ssm_scan", transfer_from=full, measure_budget=0.01)
    assert staged.configs and staged.tree is not None
    assert staged.lineage["measured_fraction"] <= 0.01 + 1e-9


# ---------------------------------------------------------------------------
# auto-sized measurement budgets (measure_budget="auto")
# ---------------------------------------------------------------------------
def test_auto_measure_budget_scales_with_donor_error():
    assert pl.auto_measure_budget(None) == pl.AUTO_BUDGET_DEFAULT
    assert pl.auto_measure_budget(0.0) == pl.AUTO_BUDGET_FLOOR  # trusted donor
    assert pl.auto_measure_budget(10.0) == pl.AUTO_BUDGET_CEIL  # junk donor
    lo, hi = pl.auto_measure_budget(0.05), pl.auto_measure_budget(0.15)
    assert pl.AUTO_BUDGET_FLOOR < lo < hi < pl.AUTO_BUDGET_CEIL


def test_donor_model_error_reads_lineage():
    donor = tune_for_archs(ARCHS, device_name="tpu_v5e", max_problems=30, families=[])
    target = tune_for_archs(
        ARCHS, device_name="tpu_v4", max_problems=30, families=[],
        transfer_from=donor, measure_budget=0.4,
    )
    # a full-measure root records model_error=None (nothing model-filled)
    assert pl.donor_model_error(donor) is None
    err = pl.donor_model_error(target)
    assert err is not None
    assert err == target.deployment.meta["tuning_lineage"]["matmul"]["model_error"]
    assert pl.donor_model_error(None) is None
    assert pl.donor_model_error(object()) is None  # no lineage: no opinion


def test_resolve_measure_budget_auto_semantics():
    donor = tune_for_archs(ARCHS, device_name="tpu_v5e", max_problems=30, families=[])
    # numeric and None pass through untouched
    assert pl.resolve_measure_budget(0.3, donor) == 0.3
    assert pl.resolve_measure_budget(None, donor) is None
    # auto without a donor = bring-up root: measure in full
    assert pl.resolve_measure_budget("auto", None) is None
    # auto with a donor = sized from its recorded model_error (the root's
    # identity lineage has none, so the default budget applies)
    got = pl.resolve_measure_budget("auto", donor)
    assert got == pl.auto_measure_budget(pl.donor_model_error(donor))
    assert got == pl.AUTO_BUDGET_DEFAULT
    assert pl.AUTO_BUDGET_FLOOR <= got <= pl.AUTO_BUDGET_CEIL


def test_fleet_auto_budget_stamps_partial_measurement():
    fleet = tune_fleet(
        ARCHS, device_names=("tpu_v5e", "tpu_v4"), families=[],
        transfer=True, measure_budget="auto", max_problems=30,
    )
    lin_root = fleet.results["tpu_v5e"].deployment.meta["tuning_lineage"]["matmul"]
    lin_next = fleet.results["tpu_v4"].deployment.meta["tuning_lineage"]["matmul"]
    assert lin_root["measured_fraction"] == 1.0  # donor-less root: full measure
    assert lin_next["source_device"] == "tpu_v5e"
    assert 0.0 < lin_next["measured_fraction"] < 1.0  # auto budget bit
