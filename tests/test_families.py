"""Generic kernel-family registry: tune -> deploy -> dispatch -> retune for
every op, plus v1-v4 blob back-compat and v5 forward-compat."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import retune
from repro.core.bundle import DeploymentBundle
from repro.core.dispatch import Deployment
from repro.core.families import (
    FamilyTuning,
    KernelFamily,
    build_family_dataset,
    family_names,
    get_family,
    is_registered,
    register_family,
    unregister_family,
)
from repro.core.tuner import FamilyTuneResult, tune, tune_family
from repro.kernels import ops
from repro.kernels.ops import FixedPolicy
from repro.kernels.ssm import DEFAULT_SSM_CONFIG, SsmConfig
from repro.kernels.wkv import DEFAULT_WKV_CONFIG, WkvConfig
from repro.core.runtime import default_runtime as rt
from repro.core.runtime import reset_default_runtime

DATA = Path(__file__).parent / "data"


@pytest.fixture(autouse=True)
def _clean_policy():
    # Fresh default runtime per test: no hand-maintained clear_* choreography.
    yield
    reset_default_runtime()


@pytest.fixture(scope="module")
def tuned():
    from repro.core.dataset import build_model_dataset, synthetic_problems

    ds = build_model_dataset(synthetic_problems(80), device_name="tpu_v5e")
    return tune(ds, n_kernels=6)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_builtin_families_registered():
    names = family_names()
    assert names[0] == "matmul"  # matmul anchors the Deployment
    assert set(names) >= {"matmul", "attention", "wkv", "ssm_scan"}
    for name in names:
        fam = get_family(name)
        assert fam.name == name
        assert len(fam.feature_names) > 0
        assert fam.default_config in fam.config_space() or name == "matmul"
        probs = fam.harvest(None)
        assert probs, name
        assert all(len(p) == fam.problem_arity for p in probs), name
        feats = fam.features(probs)
        assert feats.shape == (len(probs), len(fam.feature_names))
        assert np.all(np.isfinite(feats))


def test_get_family_unknown_raises():
    with pytest.raises(KeyError, match="unknown kernel family"):
        get_family("conv3d")


def test_register_custom_family_roundtrip():
    fam = KernelFamily(
        name="toy_op",
        config_cls=WkvConfig,
        config_space=lambda: (WkvConfig(8), WkvConfig(64)),
        default_config=WkvConfig(8),
        feature_names=("log2_s",),
        features=lambda ps: np.log2(np.asarray(ps, float).reshape(-1, 1)),
        harvest=lambda arch_ids: [(128,), (4096,)],
        perf_matrix=lambda ps, cs, dev: 1.0 + np.arange(len(ps) * len(cs), dtype=float).reshape(len(ps), len(cs)),
        policy_attr="select_toy",
        problem_arity=1,
        reference="n/a",
    )
    register_family(fam)
    try:
        assert is_registered("toy_op")
        assert get_family("toy_op") is fam
        # one registry entry is enough to ride the whole tuning pipeline
        res = tune_family("toy_op")
        assert isinstance(res, FamilyTuneResult)
        # tuple-unpack compat shim warns for one release, then goes away
        with pytest.warns(DeprecationWarning, match="configs"):
            configs, tree = res
        assert configs and tree is not None
        assert configs == res.configs and tree is res.tree
    finally:
        unregister_family("toy_op")
    assert not is_registered("toy_op")


def test_build_family_dataset_features_route_through_registry():
    ds = build_family_dataset("wkv")
    assert ds.family == "wkv"
    assert ds.features.shape == (len(ds.problems), 3)
    tr, te = ds.split(0.25)
    assert tr.family == "wkv" and te.family == "wkv"


def test_recmodel_long_tail():
    """Multiple configs win somewhere — the selectable structure exists."""
    from repro.core.recmodel import build_ssm_matrix, build_wkv_matrix

    wkv = build_wkv_matrix([(s, hd) for s in (1, 64, 512, 2048, 32768) for hd in (16, 64, 128)])
    ssm = build_ssm_matrix([(s, d) for s in (64, 512, 2048, 32768) for d in (48, 256, 1600)])
    assert len(set(wkv.argmax(1).tolist())) >= 3
    assert len(set(ssm.argmax(1).tolist())) >= 3
    assert np.all(wkv >= 0) and np.all(ssm >= 0)


# ---------------------------------------------------------------------------
# tuning: every family through the same pipeline
# ---------------------------------------------------------------------------
def test_tune_ships_all_registered_families(tuned):
    dep = tuned.deployment
    assert set(dep.family_names()) >= {"matmul", "attention", "wkv", "ssm_scan"}
    dists = dep.meta["family_distributions"]
    assert set(dists) >= {"attention", "wkv", "ssm_scan"}
    for fname in ("wkv", "ssm_scan"):
        configs, tree = dep.family_tuning(fname)
        assert configs and tree is not None
        assert tuned.family_results[fname].oracle_fraction > 0.8
    # generic select answers every family with a deployed config
    assert dep.select("wkv", (4096, 64)) in dep.family_tuning("wkv").configs
    assert dep.select_ssm(2048, 1600) in dep.family_tuning("ssm_scan").configs


def test_tune_family_rejects_matmul_and_empty():
    with pytest.raises(ValueError, match="tuned via tune"):
        tune_family("matmul")
    with pytest.raises(ValueError, match="no benchmark problems"):
        tune_family("wkv", problems=[])


def test_tune_skips_families_foreign_to_archs():
    """A dense-only arch set leaves wkv/ssm untuned instead of failing."""
    from repro.core.tuner import tune_for_archs

    res = tune_for_archs(["granite-8b"], n_kernels=4, max_problems=30)
    dep = res.deployment
    assert not dep.families.get("wkv")
    assert dep.select_wkv(4096, 64) == DEFAULT_WKV_CONFIG  # reference default


# ---------------------------------------------------------------------------
# dispatch: registry-driven hooks, family-qualified keys, policy coverage
# ---------------------------------------------------------------------------
def test_fixed_policy_covers_every_family():
    pol = FixedPolicy(wkv_config=WkvConfig(64), ssm_config=SsmConfig(64, 16))
    rt().install(pol)
    assert ops.select_wkv_config(2048, 64) == WkvConfig(64)
    assert ops.select_ssm_config(2048, 1600) == SsmConfig(64, 16)


def test_partial_policy_falls_back_to_default():
    """A matmul-only policy no longer needs duck-typed hasattr hooks."""

    class MatmulOnly:
        def select_matmul(self, m, k, n, batch):
            return "mm"

    rt().install(MatmulOnly())
    assert ops.select_wkv_config(2048, 64) is None  # op runs its default config
    assert ops.select_ssm_config(2048, 1600) is None


def test_family_qualified_cache_and_log(tuned):
    """An ssm (s, d) problem can never alias a matmul (m, k) tuple."""
    dep = tuned.deployment
    rt().install(dep)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    ops.select_ssm_config(512, 784)
    ops.select_matmul_config(512, 784, 512, 16)
    ops.select_wkv_config(512, 784)
    log = ops.selection_log()
    assert [e[0] for e in log] == ["ssm_scan", "matmul", "wkv"]
    assert isinstance(log[0][2], SsmConfig)
    assert isinstance(log[2][2], WkvConfig)
    stats = ops.shape_cache_stats()
    per = stats["per_family"]
    assert per["ssm_scan"] == {"hits": 0, "misses": 1, "size": 1}
    assert per["matmul"]["misses"] == 1 and per["wkv"]["size"] == 1
    ops.select_ssm_config(512, 784)  # memo hit under the family-qualified key
    assert ops.shape_cache_stats()["per_family"]["ssm_scan"]["hits"] == 1


def test_ssm_wkv_ops_dispatch_through_policy(tuned):
    """The model-facing ops consult the tuned policy (no hasattr hooks)."""
    import jax.numpy as jnp

    dep = tuned.deployment
    rt().install(dep)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    b, s, h, hd = 1, 8, 2, 16
    r = jnp.ones((b, s, h, hd), jnp.float32)
    ops.wkv(r, r, r, -jnp.ones_like(r), jnp.ones((h, hd)), None)
    dtx = jnp.ones((1, 8, 16), jnp.float32)
    dta = -jnp.ones((1, 8, 16, 4), jnp.float32)
    bv = jnp.ones((1, 8, 4), jnp.float32)
    ops.ssm_scan(dtx, dta, bv, bv)
    logged = {e[0]: e[1] for e in ops.selection_log()}
    assert logged["wkv"] == (8, 16)
    assert logged["ssm_scan"] == (8, 16)  # distinct families, same tuple: no clash


def test_online_policy_family_coverage(tuned):
    from repro.core.online import OnlinePolicy

    dep = tuned.deployment
    pol = OnlinePolicy(lambda p, c: 1.0, dep.configs, prior=dep)
    assert pol.select_wkv(4096, 64) == dep.select_wkv(4096, 64)
    assert pol.select_ssm(2048, 1600) == dep.select_ssm(2048, 1600)
    bare = OnlinePolicy(lambda p, c: 1.0, dep.configs)
    assert bare.select_wkv(4096, 64) == DEFAULT_WKV_CONFIG
    assert bare.select_ssm(2048, 1600) == DEFAULT_SSM_CONFIG


# ---------------------------------------------------------------------------
# blob back-compat: committed v1-v4 artifacts load with identical selections
# ---------------------------------------------------------------------------
def _expected():
    return json.loads((DATA / "expected_selections.json").read_text())


@pytest.mark.parametrize("fixture", ["dep_v1.json", "dep_v2.json"])
def test_committed_deployment_blobs_load_identically(fixture):
    exp = _expected()
    dep = Deployment.load(DATA / fixture)
    got_m = [dep.select_matmul(*p).to_dict() for p in exp["matmul_probes"]]
    got_a = [dep.select_attention(*p).to_dict() for p in exp["attention_probes"]]
    assert got_m == exp["devices"]["tpu_v5e"]["matmul"]
    assert got_a == exp["devices"]["tpu_v5e"]["attention"]
    # pre-family artifacts serve reference defaults for the new families
    assert dep.select_wkv(4096, 64) == DEFAULT_WKV_CONFIG
    assert dep.select_ssm(2048, 1600) == DEFAULT_SSM_CONFIG


@pytest.mark.parametrize("fixture", ["bundle_v3.json", "bundle_v4.json"])
def test_committed_bundle_blobs_load_identically(fixture):
    exp = _expected()
    bundle = DeploymentBundle.load(DATA / fixture)
    assert bundle.devices == ["tpu_v4", "tpu_v5e"]
    for device, want in exp["devices"].items():
        dep = bundle.deployments[device]
        got_m = [dep.select_matmul(*p).to_dict() for p in exp["matmul_probes"]]
        got_a = [dep.select_attention(*p).to_dict() for p in exp["attention_probes"]]
        assert got_m == want["matmul"], device
        assert got_a == want["attention"], device
    if fixture == "bundle_v4.json":  # provenance block survives the upgrade
        assert "train_distribution" in bundle.deployments["tpu_v5e"].meta


def test_v5_roundtrip_preserves_family_selections(tmp_path, tuned):
    dep = tuned.deployment
    path = tmp_path / "dep_v5.json"
    dep.save(path)
    blob = json.loads(path.read_text())
    assert blob["version"] == 5
    assert set(blob["families"]) == {"ssm_scan", "wkv"}
    back = Deployment.load(path)
    for p in [(1, 64), (2048, 64), (32768, 64)]:
        assert back.select_wkv(*p) == dep.select_wkv(*p)
    for p in [(2048, 1600), (32768, 1600)]:
        assert back.select_ssm(*p) == dep.select_ssm(*p)
    assert back.meta["family_distributions"] == dep.meta["family_distributions"]


def test_unknown_family_ignored_forward_compat(tuned):
    """A blob from a future build with an unknown op stays loadable."""
    blob = tuned.deployment.to_blob()
    blob["families"]["fancy_conv"] = {"configs": [{"tile": 9}], "tree": None}
    back = Deployment.from_blob(blob)
    assert "fancy_conv" not in back.families
    assert set(back.families) == {"ssm_scan", "wkv"}  # known families intact


def test_family_tree_labels_validated(tuned):
    blob = tuned.deployment.to_blob()
    bad = blob["families"]["wkv"]["tree"]
    bad["label"] = [99 for _ in bad["label"]]
    with pytest.raises(ValueError, match="families.wkv.tree"):
        Deployment.from_blob(blob)


# ---------------------------------------------------------------------------
# retune: per-(family, shape) buckets; an ssm-only shift touches only ssm
# ---------------------------------------------------------------------------
def _ssm_snapshot(n=60):
    snap = retune.TelemetrySnapshot()
    for i in range(n):
        p = (96 if i % 2 else 160, 48)
        b = retune.shape_bucket(p)
        fam = snap.counts.setdefault("ssm_scan", {})
        fam[b] = fam.get(b, 0) + 1
        snap.family_problems.setdefault("ssm_scan", {})[b] = p
        snap.n_events += 1
    return snap


def test_snapshot_buckets_per_family(tuned):
    rt().install(tuned.deployment)
    rt().set_selection_logging(True)
    rt().clear_selection_log()
    ops.select_matmul_config(512, 784, 512, 16)
    ops.select_ssm_config(512, 784)
    ops.select_wkv_config(2048, 64)
    snap = retune.TelemetrySnapshot.from_selection_log(ops.selection_log())
    assert snap.families() == ["matmul", "ssm_scan", "wkv"]
    assert snap.family_events("matmul") == 1 and snap.family_events("wkv") == 1
    # the same bucket tuple under different families never merges
    assert retune.shape_bucket((512, 784)) in snap.counts["ssm_scan"]
    assert retune.shape_bucket((512, 784)) not in snap.counts["matmul"]
    other = retune.TelemetrySnapshot.from_selection_log(
        [("ssm_scan", (512, 784), None)]
    )
    snap.merge(other)
    assert snap.family_events("ssm_scan") == 2


def test_ssm_only_shift_drifts_and_retunes_only_ssm(tuned):
    dep = tuned.deployment
    snap = _ssm_snapshot()
    rep_mm = retune.detect_drift(snap, dep, family="matmul")
    rep_ssm = retune.detect_drift(snap, dep, family="ssm_scan")
    assert not rep_mm.triggered and rep_mm.score == 0.0  # no matmul traffic
    assert rep_ssm.triggered and rep_ssm.family == "ssm_scan"
    assert rep_ssm.unseen_fraction > 0.9  # serving shapes the harvest never saw
    out = retune.incremental_retune(dep, snap, family="ssm_scan", report=rep_ssm)
    nd = out.deployment
    assert out.family == "ssm_scan" and out.n_harvested > 0
    assert nd.configs == dep.configs  # matmul untouched
    assert nd.classifier is dep.classifier
    assert nd.attention_tree is dep.attention_tree
    assert nd.family_tuning("ssm_scan").tree is not dep.family_tuning("ssm_scan").tree
    assert nd.meta["retune"]["family"] == "ssm_scan"
    # the retuned family is measurably closer to the live distribution
    rep2 = retune.detect_drift(snap, nd, family="ssm_scan")
    assert rep2.score < rep_ssm.score
    assert nd.select_ssm(96, 48) in nd.family_tuning("ssm_scan").configs


def test_engine_maybe_retune_handles_ssm_only_traffic(tuned):
    from test_retune import _ToyModel

    from repro.serve.engine import ServingEngine

    rt().install(tuned.deployment)
    eng = ServingEngine(_ToyModel(), params={}, max_batch=1, cache_len=16,
                        retune_interval=10_000, retune_min_events=8)
    rt().clear_selection_log()
    for _ in range(40):
        ops.select_ssm_config(96, 48)
    ev = eng.maybe_retune()
    assert ev is not None and ev.swapped and ev.families == ("ssm_scan",)
    assert eng.deployment.configs == tuned.deployment.configs  # matmul untouched
    assert eng.deployment.meta["retune"]["family"] == "ssm_scan"


# ---------------------------------------------------------------------------
# codegen: the generated launcher routes every family
# ---------------------------------------------------------------------------
def test_bundle_to_python_family_routing(tuned):
    from repro.core.codegen import bundle_to_python

    bundle = DeploymentBundle({"tpu_v5e": tuned.deployment})
    ns = {}
    exec(bundle_to_python(bundle), ns)  # noqa: S102 — generated launcher code
    assert set(ns["FAMILY_SELECTORS"]) == {"matmul", "attention", "ssm_scan", "wkv"}
    for fname in ("attention", "wkv", "ssm_scan"):
        fam = get_family(fname)
        _cfgs, tree = tuned.deployment.family_tuning(fname)
        probs = fam.harvest(None)[:4]
        feats = fam.features(probs)
        want = list(tree.predict(feats))
        got = [ns["select_kernel_family"](fname, "tpu_v5e", *row) for row in feats]
        assert got == want, fname
    with pytest.raises(KeyError):
        ns["select_kernel_family"]("conv3d", "tpu_v5e", 1.0)


# ---------------------------------------------------------------------------
# fig7 artifact idempotency
# ---------------------------------------------------------------------------
def test_fig7_merge_is_idempotent(tmp_path, monkeypatch):
    import benchmarks.common as common
    import benchmarks.fig7_end_to_end as fig7

    monkeypatch.setattr(common, "OUT_DIR", tmp_path)
    art = tmp_path / "fig7_end_to_end.json"
    art.write_text(json.dumps({
        "device": "tpu_v5e",
        "per_arch_ms": {"other-arch": {"tuned8": 1.0}, "phi4-mini-3.8b": {"tuned8": 999.0}},
    }))
    merged = fig7._merge_artifact({"phi4-mini-3.8b": {"tuned8": 2.0}})
    # re-measured arch replaced (no duplicate provenance), others preserved
    assert merged["phi4-mini-3.8b"] == {"tuned8": 2.0}
    assert merged["other-arch"] == {"tuned8": 1.0}
    # idempotent: merging the same rows again changes nothing
    assert fig7._merge_artifact({"phi4-mini-3.8b": {"tuned8": 2.0}}) == merged
    # unreadable artifact: rebuild from this run alone
    art.write_text("{corrupt")
    assert fig7._merge_artifact({"a": {"tuned8": 3.0}}) == {"a": {"tuned8": 3.0}}


def test_perf_gate_gates_family_rows():
    from benchmarks.perf_gate import collect_metrics

    gated, _ = collect_metrics(None, {"rows": [
        ["families_wkv_speedup", 2.5, "derived"],
        ["fig7_x_tuned8_ms", 100.0, "derived"],
        ["families_wkv_other", 9.9, "not gated"],
    ]})
    assert gated["families_wkv_speedup"] == (2.5, "higher")
    assert gated["fig7_x_tuned8_ms"] == (100.0, "lower")
    assert "families_wkv_other" not in gated
