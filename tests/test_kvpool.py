"""Paged KV pool regression tests: probing, block accounting, gather/scatter.

The pool is exercised standalone against the serving toy-model cache layout
(batch-leading "k", layer-leading "mem") plus a replicated-leaf variant, and
its gather/scatter round-trip is pinned against the dense ``_scatter_slot``
path it replaced.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.engine import _scatter_slot
from repro.serve.kvpool import KVPool, probe_cache_layout


class ToyModel:
    """Echo cache: "k" is (B, L) batch-leading, "mem" is (2, B, 4) layer-leading."""

    def init_cache(self, b, cache_len):
        return {
            "k": jnp.zeros((b, cache_len), jnp.float32),
            "mem": jnp.zeros((2, b, 4), jnp.float32),
        }


class ReplicatedModel:
    """Adds a leaf with neither a batch nor a length axis (shared rotary table)."""

    def init_cache(self, b, cache_len):
        return {
            "k": jnp.zeros((b, cache_len, 2), jnp.float32),
            "rope": jnp.zeros((cache_len, 8), jnp.float32)[:16],  # fixed (16, 8)
        }


# ---------------------------------------------------------------------------
# layout probing
# ---------------------------------------------------------------------------
def _spec(specs, name):
    return next(s for s in specs if f"'{name}'" in s.path)


def test_probe_classifies_paged_and_lane_leaves():
    specs, _ = probe_cache_layout(ToyModel().init_cache, cache_len=32, block_size=8)
    k, mem = _spec(specs, "k"), _spec(specs, "mem")
    assert k.kind == "paged" and (k.batch_axis, k.length_axis) == (0, 1)
    assert mem.kind == "lane"  # no length axis: lives per-lane, dense
    assert mem.batch_axis == 1


def test_probe_classifies_replicated_leaf():
    specs, _ = probe_cache_layout(
        ReplicatedModel().init_cache, cache_len=32, block_size=8
    )
    assert _spec(specs, "k").kind == "paged"
    assert _spec(specs, "rope").kind == "replicated"


def test_probe_rejects_structure_changes():
    def shifty(b, cache_len):
        if b == 3:  # structure depends on batch: not a poolable cache
            return {"k": jnp.zeros((b, cache_len))}
        return {"k": jnp.zeros((b, cache_len)), "extra": jnp.zeros((b, 2))}

    with pytest.raises(ValueError):
        probe_cache_layout(shifty, cache_len=32, block_size=8)


# ---------------------------------------------------------------------------
# block accounting
# ---------------------------------------------------------------------------
def _pool(**kw):
    kw.setdefault("lanes", 4)
    kw.setdefault("cache_len", 32)
    kw.setdefault("block_size", 8)
    return KVPool(ToyModel(), **kw)


def test_pool_defaults_and_invariants():
    pool = _pool()  # 4 lanes x 4 blocks/lane = 16 blocks by default
    assert pool.n_blocks == 16 and pool.block_size == 8
    assert pool.free_blocks == 16 and pool.used_blocks == 0
    assert pool.blocks_needed(1) == 1 and pool.blocks_needed(8) == 1
    assert pool.blocks_needed(9) == 2 and pool.blocks_needed(32) == 4
    st = pool.stats()
    assert st["n_blocks"] == 16 and st["lanes"] == 4 and st["lanes_used"] == 0


def test_pool_rejects_bad_geometry():
    with pytest.raises(ValueError):
        _pool(block_size=7)  # does not divide cache_len
    with pytest.raises(ValueError):
        _pool(n_blocks=3)  # fewer blocks than one full lane needs


def test_ensure_release_and_fragmentation():
    pool = _pool(lanes=2, n_blocks=6)
    assert pool.ensure(0, 20)  # 3 blocks
    assert pool.ensure(1, 10)  # 2 blocks
    assert pool.used_blocks == 5 and pool.free_blocks == 1
    assert pool.free_blocks + pool.used_blocks == pool.n_blocks
    # tables are disjoint and never reference the scratch block 0
    t0, t1 = pool.block_table(0), pool.block_table(1)
    assert not (set(t0) & set(t1)) and 0 not in t0 + t1
    # growth under pressure: one more block fits, the next does not
    assert pool.ensure(0, 28) and pool.used_blocks == 6
    assert not pool.ensure(1, 24)  # pool dry: caller must preempt
    assert pool.block_table(1) == t1  # failed ensure leaves the table intact
    freed = pool.release(0)
    assert freed == 4 and pool.free_blocks == 4 and pool.block_table(0) == ()
    # released blocks are reusable immediately, fragmentation notwithstanding
    assert pool.ensure(1, 24) and pool.used_blocks == 3  # grew 2 -> 3 blocks


def test_can_fit_tracks_free_and_retired():
    pool = _pool(lanes=2, n_blocks=4)
    assert pool.can_fit(32)
    pool.ensure(0, 24)  # 3 of 4 blocks
    assert pool.can_fit(8) and not pool.can_fit(9)
    pool.retire(0)  # lazily reclaimable: counts toward can_fit again
    assert pool.retired_blocks == 3 and pool.can_fit(32)


def test_retire_is_lazy_until_pressure():
    pool = _pool(lanes=2, n_blocks=4)
    cache1 = ToyModel().init_cache(1, 32)
    cache1 = {**cache1, "k": cache1["k"].at[0, :8].set(5.0)}
    pool.ensure(0, 8)
    pool.admit(0, cache1)
    pool.retire(0)
    # retired content is still readable (used for completed-request inspection)
    k = np.asarray(pool.gather([0])["k"])
    assert k[0, :8].sum() == 40.0
    # allocation pressure harvests the retired lane's blocks
    assert pool.ensure(1, 32)  # needs all 4 blocks; only 3 were free
    assert pool.retired_blocks == 0 and pool.block_table(0) == ()


# ---------------------------------------------------------------------------
# gather / scatter semantics
# ---------------------------------------------------------------------------
def _prefill_cache(tokens, cache_len=32):
    """Single-sequence cache the way ToyModel's prefill would build it."""
    cache = ToyModel().init_cache(1, cache_len)
    cache["k"] = cache["k"].at[0, : len(tokens)].set(jnp.asarray(tokens, jnp.float32))
    cache["mem"] = cache["mem"] + 1.0
    return cache


def test_admit_gather_round_trip():
    pool = _pool()
    toks = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0]  # spans two blocks
    cache1 = _prefill_cache(toks)
    pool.ensure(2, len(toks))
    pool.admit(2, cache1)
    dense = pool.gather([0, 1, 2, 3])
    np.testing.assert_array_equal(
        np.asarray(dense["k"])[2, : len(toks)], np.asarray(toks)
    )
    assert np.asarray(dense["k"])[[0, 1, 3]].sum() == 0  # other lanes untouched
    np.testing.assert_array_equal(np.asarray(dense["mem"])[:, 2], 1.0)


def test_scatter_gather_matches_dense_scatter_slot():
    """Paged admit+gather must reproduce the dense ``_scatter_slot`` layout."""
    lanes, cache_len = 4, 32
    pool = _pool(lanes=lanes, cache_len=cache_len)
    dense = ToyModel().init_cache(lanes, cache_len)
    rng = np.random.default_rng(0)
    for lane in (0, 2, 3):
        toks = rng.integers(1, 9, size=int(rng.integers(3, 17)))
        cache1 = _prefill_cache(toks, cache_len)
        pool.ensure(lane, len(toks))
        pool.admit(lane, cache1)
        dense = {
            k: _scatter_slot(dense[k], cache1[k], slot=lane, max_batch=lanes)
            for k in dense
        }
    got = pool.gather(range(lanes))
    for key in dense:
        np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(dense[key]))


def test_scatter_writes_back_and_scratch_stays_zero():
    pool = _pool(lanes=2, cache_len=16, block_size=8, n_blocks=4)
    pool.ensure(0, 16)
    pool.ensure(1, 8)
    view = pool.gather([0, 1])
    view["k"] = view["k"].at[0, 11].set(7.0)  # lane 0, second block
    view["k"] = view["k"].at[1, 3].set(2.0)
    view["mem"] = view["mem"] + 0.5
    pool.scatter([0, 1], view)
    back = pool.gather([0, 1])
    assert np.asarray(back["k"])[0, 11] == 7.0
    assert np.asarray(back["k"])[1, 3] == 2.0
    assert np.asarray(back["mem"]).min() == 0.5
    # lanes with short tables read zeros past their allocation (scratch block)
    pool2 = _pool(lanes=2, cache_len=16, block_size=8, n_blocks=4)
    pool2.ensure(0, 8)  # one block only
    v = pool2.gather([0, 1])
    v["k"] = v["k"] + 1.0  # writes into the unallocated tail land in scratch
    pool2.scatter([0, 1], v)
    after = np.asarray(pool2.gather([0, 1])["k"])
    assert after[0, :8].min() == 1.0
    assert after[0, 8:].sum() == 0  # scratch block re-zeroed, tail reads clean
    assert after[1].sum() == 0


def test_dense_degenerate_mode_matches_seed_layout():
    """block_size=None keeps one dense block per lane: gather == init_cache."""
    pool = KVPool(ToyModel(), lanes=3, cache_len=16, block_size=None)
    assert pool.block_size == 16 and pool.n_blocks == 3
    base = ToyModel().init_cache(3, 16)
    for lane in range(3):
        pool.ensure(lane, 16)
    got = pool.gather(range(3))
    for key in base:
        assert got[key].shape == base[key].shape
        np.testing.assert_array_equal(np.asarray(got[key]), np.asarray(base[key]))
    toks = [4.0, 2.0]
    pool.ensure(1, len(toks))
    pool.admit(1, _prefill_cache(toks, 16))
    np.testing.assert_array_equal(np.asarray(pool.gather(range(3))["k"])[1, :2], toks)


# ---------------------------------------------------------------------------
# prefix sharing (refcounts, index lifecycle)
# ---------------------------------------------------------------------------
def test_refcount_lifecycle_and_shared_release():
    pool = _pool(lanes=3, n_blocks=6)  # block_size=8
    toks = list(range(100, 120))  # 20 tokens: 2 full blocks + a partial third
    pool.ensure(0, 21)  # 3 blocks
    assert pool.register_prefix(0, toks) == 2  # only fully-covered blocks
    matched = pool.match_prefix(toks + [1, 2])
    assert matched == list(pool.block_table(0))[:2]
    pool.alias(1, matched)
    assert pool.block_refcount(matched[0]) == 2
    assert pool.lane_holds_shared(0) and pool.lane_holds_shared(1)
    assert pool.shared_blocks == 2
    # releasing the original owner frees only its private frontier block
    assert pool.release(0) == 1
    assert pool.match_prefix(toks) == matched  # index intact: lane 1 holds
    # last holder gone: blocks free and their index entries die with them
    assert pool.release(1) == 2
    assert pool.match_prefix(toks, peek=True) == []
    assert pool.shared_blocks == 0 and pool.free_blocks == 6


def test_match_prefix_stops_at_content_divergence():
    pool = _pool(lanes=2, n_blocks=6)
    toks = list(range(24))  # 3 full blocks
    pool.ensure(0, 24)
    assert pool.register_prefix(0, toks) == 3
    diverged = toks[:8] + [99] + toks[9:]
    assert pool.match_prefix(diverged, peek=True) == [pool.block_table(0)[0]]
    assert pool.match_prefix([7] + toks[1:], peek=True) == []


def test_admit_prefix_survives_reclaiming_its_own_lane():
    """A follow-up landing in the lane that owns its prefix must keep it:
    the match is reserved before the lane's previous tenant is released."""
    pool = _pool(lanes=2, n_blocks=4)
    toks = list(range(16))
    pool.ensure(0, 17)  # 2 full-body blocks + the decode frontier
    pool.register_prefix(0, toks)
    shared = list(pool.block_table(0))[:2]
    pool.retire(0)
    assert pool.admit_prefix(0, toks + [5]) == 16
    assert list(pool.block_table(0)) == shared
    assert pool.block_refcount(shared[0]) == 1  # reserved, then released once


def test_retired_lane_keeps_prefix_until_harvested():
    pool = _pool(lanes=2, n_blocks=4)
    toks = list(range(16))
    pool.ensure(0, 16)
    pool.register_prefix(0, toks)
    pool.retire(0)
    assert pool.match_prefix(toks, peek=True) == list(pool.block_table(0))
    # block pressure harvests the retired lane: the cached prefix dies
    assert pool.ensure(1, 32)
    assert pool.match_prefix(toks, peek=True) == []


def test_alias_rejects_bad_targets():
    pool = _pool(lanes=2, n_blocks=4)
    pool.ensure(0, 8)
    with pytest.raises(ValueError):
        pool.alias(1, [3])  # unallocated block
    pool.ensure(1, 8)
    with pytest.raises(ValueError):
        pool.alias(1, list(pool.block_table(0)))  # non-empty table


def test_stats_track_sharing_and_fragmentation():
    pool = _pool(lanes=2, n_blocks=6)
    pool.ensure(0, 9)  # 2 blocks = 16 slots
    pool.note_tokens(0, 9)
    st = pool.stats()
    assert st["fragmentation"] == pytest.approx(1 - 9 / 16)
    pool.match_prefix(range(8))  # miss
    toks = list(range(8))
    pool.register_prefix(0, toks)
    hit = pool.match_prefix(toks)
    pool.match_prefix(toks, peek=True)  # router probe: not counted
    pool.alias(1, hit)
    st = pool.stats()
    assert st["prefix_lookups"] == 2 and st["prefix_hits"] == 1
    assert st["prefix_hit_rate"] == 0.5 and st["prefix_hit_tokens"] == 8
    assert st["shared_blocks"] == 1


def test_replicated_leaf_passes_through_unpooled():
    pool = KVPool(ReplicatedModel(), lanes=2, cache_len=32, block_size=8)
    pool.ensure(0, 8)
    view = pool.gather([0, 1])
    assert view["rope"].shape == (16, 8)
    view["rope"] = view["rope"] + 3.0
    view["k"] = view["k"].at[0, 1, :].set(9.0)
    pool.scatter([0, 1], view)
    back = pool.gather([0, 1])
    assert np.asarray(back["rope"]).min() == 3.0  # adopted wholesale
    assert np.asarray(back["k"])[0, 1].min() == 9.0
