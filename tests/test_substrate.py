"""Data pipeline, checkpointing, fault-tolerance, serving engine tests."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, TokenPipeline, reshard
from repro.core.faults import PreemptionGuard, StragglerDetector, elastic_plan
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_data_deterministic():
    cfg = registry.get("granite-8b").reduced()
    pipe = TokenPipeline(cfg, DataConfig(global_batch=4, seq_len=16))
    a, b = pipe.batch(7), pipe.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_data_host_sharding():
    cfg = registry.get("granite-8b").reduced()
    d = DataConfig(global_batch=8, seq_len=16, host_count=1)
    full = TokenPipeline(cfg, d).batch(3)["tokens"]
    shards = [
        TokenPipeline(cfg, reshard(d, i, 4)).batch(3)["tokens"] for i in range(4)
    ]
    for s in shards:
        assert s.shape == (2, 16)
    # shards are distinct streams (host index folded into the rng)
    assert len({s.tobytes() for s in shards}) == 4
    assert full.shape == (8, 16)


def test_data_markov_structure():
    """The chain must be learnable: successor entropy << uniform."""
    cfg = registry.get("granite-8b").reduced()
    pipe = TokenPipeline(cfg, DataConfig(global_batch=16, seq_len=128))
    toks = pipe.batch(0)["tokens"]
    # Empirical check: repeated (prev -> next) pairs are common.
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs[(int(a), int(b))] = pairs.get((int(a), int(b)), 0) + 1
    repeats = sum(1 for v in pairs.values() if v > 1)
    assert repeats > 20  # uniform-random pairs over 256^2 would almost never repeat


def test_data_modality_stubs():
    vlm = registry.get("llama-3.2-vision-90b").reduced()
    b = TokenPipeline(vlm, DataConfig(global_batch=2, seq_len=8)).batch(0)
    assert b["image_embs"].shape == (2, vlm.n_image_tokens, vlm.d_model)
    audio = registry.get("seamless-m4t-large-v2").reduced()
    b = TokenPipeline(audio, DataConfig(global_batch=2, seq_len=8)).batch(0)
    assert b["frames"].shape == (2, 8, audio.d_model)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_data_property_resume(step, hosts):
    """Property: batch(step) is a pure function of (seed, step, shard)."""
    cfg = registry.get("granite-8b").reduced()
    d = DataConfig(global_batch=8, seq_len=8, host_count=hosts, host_index=hosts - 1)
    p1, p2 = TokenPipeline(cfg, d), TokenPipeline(cfg, d)
    np.testing.assert_array_equal(p1.batch(step)["tokens"], p2.batch(step)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "nested": {"b": jnp.arange(5), "c": jnp.float32(x)}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, _tree(2.0), extra={"note": "hi"})
    assert mgr.latest_step() == 10
    got, extra = mgr.restore(10, jax.eval_shape(lambda: _tree()))
    np.testing.assert_allclose(got["a"], np.full((4, 3), 2.0))
    np.testing.assert_array_equal(got["nested"]["b"], np.arange(5))
    assert extra == {"note": "hi"}


def test_ckpt_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.steps() == [3, 4]
    got = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert got is not None and got[0] == 4
    np.testing.assert_allclose(got[1]["a"], np.full((4, 3), 4.0))


def test_ckpt_atomicity_torn_write(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree())
    # simulate a crash mid-write: uncommitted tmp dir + missing manifest
    torn = tmp_path / "step_2.tmp"
    torn.mkdir()
    (torn / "junk.npy").write_bytes(b"xx")
    uncommitted = tmp_path / "step_3"
    uncommitted.mkdir()  # no manifest => not committed
    assert mgr.steps() == [1]
    mgr.save(4, _tree())  # GC removes the torn tmp
    assert not torn.exists()


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save_async(5, _tree(5.0))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_ckpt_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((9, 9)), "nested": {"b": jnp.arange(5), "c": jnp.float32(0)}}
    with pytest.raises(ValueError):
        mgr.restore(1, jax.eval_shape(lambda: bad))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_straggler_detector():
    det = StragglerDetector(window=20, threshold=2.0, warmup=5)
    for _ in range(10):
        assert not det.observe(0.10)
    assert det.observe(0.35)  # 3.5x median
    assert len(det.flagged) == 1
    assert not det.observe(0.12)


def test_straggler_start_stop():
    det = StragglerDetector(warmup=1)
    for _ in range(3):
        det.start()
        time.sleep(0.01)
        det.stop()
    assert len(det.times) == 3 and det.median() > 0


def test_preemption_guard_in_process():
    with PreemptionGuard() as g:
        assert not g.preempted
        g.request()
        assert g.preempted


def test_preemption_guard_thread_signal():
    import os
    import signal as _sig

    with PreemptionGuard(signals=(_sig.SIGUSR1,)) as g:
        threading.Thread(target=lambda: os.kill(os.getpid(), _sig.SIGUSR1)).start()
        for _ in range(100):
            if g.preempted:
                break
            time.sleep(0.01)
        assert g.preempted


def test_elastic_plan():
    d = DataConfig(global_batch=32, seq_len=8, host_count=4, host_index=0)
    ok = elastic_plan(d, 1, 8)
    assert ok.ok and ok.data.host_count == 8 and ok.data.local_batch == 4
    assert not elastic_plan(d, 0, 5).ok  # 32 % 5 != 0
    assert not elastic_plan(d, 9, 8).ok
    assert not elastic_plan(d, 0, 0).ok


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_lm():
    cfg = registry.get("granite-8b").reduced()
    model = build_model(cfg, dtype=jnp.float32, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_serves_all(tiny_lm):
    cfg, model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=2, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=5).astype(np.int32), max_new_tokens=6)
        for i in range(5)
    ]
    eng.run(reqs)
    for r in reqs:
        assert r.done and len(r.output) == 6
        assert all(0 <= t < cfg.padded_vocab() for t in r.output)


def test_engine_greedy_matches_manual(tiny_lm):
    """Engine output == manual prefill+decode greedy loop for one request."""
    cfg, model, params = tiny_lm
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = ServingEngine(model, params, max_batch=2, cache_len=64, prefill_buckets=(8,))
    req = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng.run([req])

    batch = {"tokens": jnp.asarray(prompt[None, :])}
    logits, cache = model.prefill(params, batch, 64)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = 8
    for _ in range(4):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32), jnp.asarray([pos], jnp.int32)
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    assert req.output == toks


def test_engine_continuous_batching(tiny_lm):
    """More requests than slots: the engine must recycle slots."""
    cfg, model, params = tiny_lm
    eng = ServingEngine(model, params, max_batch=2, cache_len=64)
    rng = np.random.default_rng(1)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                max_new_tokens=3 + i % 3)
        for i in range(6)
    ]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert eng.steps < 40  # batched, not sequential worst-case
